//! [`StorageSim`]: the facade tying devices, page cache and backing
//! files together.
//!
//! Each simulated device owns a directory under the sim root; reads and
//! writes perform *real* file I/O there (so checkpoints can actually be
//! restored and corpora actually decoded) while service timing is paced
//! by the [`Device`] queueing model.  This is the layer every consumer
//! (pipeline map functions, the checkpoint saver, IOR) talks to — the
//! equivalent of the paper's "file system adapter" interface (Fig. 1).

use std::collections::HashMap;
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use super::device::{Device, DeviceModel, Dir, IoObserver, NullObserver};
use super::page_cache::PageCache;

/// A path on a simulated device: `(device, relative path)`.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct SimPath {
    pub device: String,
    pub rel: String,
}

impl SimPath {
    pub fn new(device: impl Into<String>, rel: impl Into<String>) -> Self {
        SimPath { device: device.into(), rel: rel.into() }
    }

    /// Parse `"device://rel/path"` (the paper's "substituting the
    /// prefix of a file path" idiom, §II).
    pub fn parse(s: &str) -> Result<SimPath> {
        let (dev, rel) = s
            .split_once("://")
            .ok_or_else(|| anyhow!("expected device://path, got {s:?}"))?;
        if dev.is_empty() || rel.is_empty() {
            return Err(anyhow!("empty device or path in {s:?}"));
        }
        Ok(SimPath::new(dev, rel))
    }
}

impl std::fmt::Display for SimPath {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}://{}", self.device, self.rel)
    }
}

/// The simulated storage system: devices + page cache + backing dir.
pub struct StorageSim {
    root: PathBuf,
    devices: HashMap<String, Arc<Device>>,
    cache: PageCache,
}

impl StorageSim {
    /// Create a sim rooted at `root` with the given device models.
    /// `cache_capacity` = 0 reproduces the paper's cold-cache protocol.
    pub fn new(
        root: impl Into<PathBuf>,
        models: Vec<DeviceModel>,
        cache_capacity: u64,
        observer: Arc<dyn IoObserver>,
    ) -> Result<Self> {
        let root = root.into();
        let mut devices = HashMap::new();
        for m in models {
            std::fs::create_dir_all(root.join(&m.name))
                .with_context(|| format!("mkdir device dir {}", m.name))?;
            devices.insert(
                m.name.clone(),
                Arc::new(Device::new(m, Arc::clone(&observer))),
            );
        }
        Ok(StorageSim { root, devices, cache: PageCache::new(cache_capacity) })
    }

    /// Convenience: no tracing, no cache.
    pub fn cold(root: impl Into<PathBuf>, models: Vec<DeviceModel>) -> Result<Self> {
        Self::new(root, models, 0, Arc::new(NullObserver))
    }

    pub fn device(&self, name: &str) -> Result<&Arc<Device>> {
        self.devices
            .get(name)
            .ok_or_else(|| anyhow!("unknown device {name:?}"))
    }

    pub fn device_names(&self) -> Vec<String> {
        let mut v: Vec<_> = self.devices.keys().cloned().collect();
        v.sort();
        v
    }

    /// Absolute backing path for a sim path.
    pub fn backing_path(&self, p: &SimPath) -> PathBuf {
        self.root.join(&p.device).join(&p.rel)
    }

    pub fn cache(&self) -> &PageCache {
        &self.cache
    }

    /// Read a whole file through the device model (tf.read_file()).
    /// Page-cache hits bypass the device.
    pub fn read(&self, p: &SimPath) -> Result<Vec<u8>> {
        let dev = self.device(&p.device)?;
        let path = self.backing_path(p);
        let size = std::fs::metadata(&path)
            .with_context(|| format!("stat {p}"))?
            .len();
        let key = p.to_string();
        if self.cache.access(&key, size) {
            // Warm: served from memory, no device charge.
            return std::fs::read(&path).with_context(|| format!("read {p}"));
        }
        dev.transfer(Dir::Read, size, || {
            std::fs::read(&path).with_context(|| format!("read {p}"))
        })
    }

    /// Write a whole file through the device model (checkpoint path).
    pub fn write(&self, p: &SimPath, data: &[u8]) -> Result<()> {
        let dev = self.device(&p.device)?;
        let path = self.backing_path(p);
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        dev.transfer(Dir::Write, data.len() as u64, || -> Result<()> {
            let mut f = std::fs::File::create(&path)
                .with_context(|| format!("create {p}"))?;
            f.write_all(data)?;
            Ok(())
        })?;
        // Written data lands in the page cache (ext4 journaling
        // behaviour the paper describes in §V-C).
        self.cache.access(&p.to_string(), data.len() as u64);
        Ok(())
    }

    /// Copy a file between devices, paying a read on `src`'s device and
    /// a write on `dst`'s (the burst-buffer drain path).
    pub fn copy(&self, src: &SimPath, dst: &SimPath) -> Result<u64> {
        let data = self.read(src)?;
        self.write(dst, &data)?;
        Ok(data.len() as u64)
    }

    /// Remove a file (checkpoint retention cleanup).
    pub fn remove(&self, p: &SimPath) -> Result<()> {
        let _ = self.device(&p.device)?;
        self.cache.invalidate(&p.to_string());
        std::fs::remove_file(self.backing_path(p))
            .with_context(|| format!("remove {p}"))
    }

    pub fn exists(&self, p: &SimPath) -> bool {
        self.backing_path(p).exists()
    }

    pub fn file_size(&self, p: &SimPath) -> Result<u64> {
        Ok(std::fs::metadata(self.backing_path(p))?.len())
    }

    /// Pace a read of `bytes` through the device model *without* any
    /// backing-file I/O.  Used by bandwidth probes (IOR, Table I)
    /// where only the service-time envelope matters — backing-store
    /// speed must not cap the modelled device.
    pub fn probe_read(&self, device: &str, bytes: u64) -> Result<()> {
        self.device(device)?.transfer(Dir::Read, bytes, || ());
        Ok(())
    }

    /// Pacing-only write probe (see [`probe_read`](Self::probe_read)).
    pub fn probe_write(&self, device: &str, bytes: u64) -> Result<()> {
        self.device(device)?.transfer(Dir::Write, bytes, || ());
        Ok(())
    }

    /// `syncfs()` on the backing filesystem of a device directory —
    /// the paper calls this after every checkpoint (§III-C).
    pub fn syncfs(&self, device: &str) -> Result<()> {
        let _ = self.device(device)?;
        let dir = std::fs::File::open(self.root.join(device))?;
        let rc = unsafe { libc::syncfs(std::os::fd::AsRawFd::as_raw_fd(&dir)) };
        if rc != 0 {
            return Err(anyhow!("syncfs failed: {}",
                               std::io::Error::last_os_error()));
        }
        Ok(())
    }

    /// Drop the simulated page cache (the paper's
    /// `echo 1 > /proc/sys/vm/drop_caches`).
    pub fn drop_caches(&self) {
        self.cache.drop_all();
    }

    /// List files under a device-relative directory, sorted.
    pub fn list(&self, device: &str, rel_dir: &str) -> Result<Vec<SimPath>> {
        let _ = self.device(device)?;
        let dir = self.root.join(device).join(rel_dir);
        if !dir.exists() {
            return Ok(Vec::new());
        }
        let mut out: Vec<PathBuf> = Vec::new();
        collect_files(&dir, &mut out)?;
        let root = self.root.join(device);
        let mut paths: Vec<SimPath> = out
            .into_iter()
            .map(|p| {
                let rel = p
                    .strip_prefix(&root)
                    .expect("backing path under device root")
                    .to_string_lossy()
                    .into_owned();
                SimPath::new(device, rel)
            })
            .collect();
        paths.sort_by(|a, b| a.rel.cmp(&b.rel));
        Ok(paths)
    }
}

fn collect_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_files(&path, out)?;
        } else {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::device::DeviceModel;

    fn fast_model(name: &str) -> DeviceModel {
        DeviceModel {
            name: name.into(),
            read_bw: 1e9,
            write_bw: 1e9,
            read_lat: 0.0,
            write_lat: 0.0,
            channels: 8,
            elevator: vec![(1, 1.0)],
            time_scale: 1000.0,
        }
    }

    fn sim(tag: &str) -> StorageSim {
        let dir = std::env::temp_dir().join(format!("dlio-sim-test-{tag}-{}",
            std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        StorageSim::cold(dir, vec![fast_model("ssd"), fast_model("hdd")])
            .unwrap()
    }

    #[test]
    fn simpath_parse_and_display() {
        let p = SimPath::parse("ssd://a/b.bin").unwrap();
        assert_eq!(p.device, "ssd");
        assert_eq!(p.rel, "a/b.bin");
        assert_eq!(p.to_string(), "ssd://a/b.bin");
        assert!(SimPath::parse("nope").is_err());
        assert!(SimPath::parse("://x").is_err());
    }

    #[test]
    fn write_read_roundtrip() {
        let s = sim("rw");
        let p = SimPath::new("ssd", "dir/file.bin");
        s.write(&p, b"hello world").unwrap();
        assert_eq!(s.read(&p).unwrap(), b"hello world");
        assert_eq!(s.file_size(&p).unwrap(), 11);
    }

    #[test]
    fn read_missing_file_errors() {
        let s = sim("missing");
        assert!(s.read(&SimPath::new("ssd", "nope.bin")).is_err());
    }

    #[test]
    fn unknown_device_errors() {
        let s = sim("unknown");
        assert!(s.read(&SimPath::new("tape", "x")).is_err());
        assert!(s.device("tape").is_err());
    }

    #[test]
    fn copy_moves_bytes_across_devices() {
        let s = sim("copy");
        let src = SimPath::new("ssd", "x.bin");
        let dst = SimPath::new("hdd", "x.bin");
        s.write(&src, &vec![7u8; 1024]).unwrap();
        let n = s.copy(&src, &dst).unwrap();
        assert_eq!(n, 1024);
        assert_eq!(s.read(&dst).unwrap(), vec![7u8; 1024]);
    }

    #[test]
    fn remove_deletes_backing_file() {
        let s = sim("rm");
        let p = SimPath::new("ssd", "x.bin");
        s.write(&p, b"x").unwrap();
        assert!(s.exists(&p));
        s.remove(&p).unwrap();
        assert!(!s.exists(&p));
    }

    #[test]
    fn list_returns_sorted_recursive() {
        let s = sim("list");
        for name in ["b/2.bin", "a/1.bin", "c.bin"] {
            s.write(&SimPath::new("ssd", name), b"x").unwrap();
        }
        let files = s.list("ssd", "").unwrap();
        let rels: Vec<_> = files.iter().map(|p| p.rel.as_str()).collect();
        assert_eq!(rels, vec!["a/1.bin", "b/2.bin", "c.bin"]);
    }

    #[test]
    fn syncfs_succeeds_on_real_fs() {
        let s = sim("sync");
        s.write(&SimPath::new("ssd", "x.bin"), b"x").unwrap();
        s.syncfs("ssd").unwrap();
    }

    #[test]
    fn warm_cache_serves_without_device() {
        let dir = std::env::temp_dir()
            .join(format!("dlio-sim-test-warm-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        // Slow device (1 MB/s, unscaled) + big cache: second read must
        // be near-instant.
        let model = DeviceModel {
            name: "slow".into(),
            read_bw: 1e6,
            write_bw: 1e9,
            read_lat: 0.0,
            write_lat: 0.0,
            channels: 1,
            elevator: vec![(1, 1.0)],
            time_scale: 1.0,
        };
        let s = StorageSim::new(dir, vec![model], 1 << 30,
                                Arc::new(crate::storage::device::NullObserver))
            .unwrap();
        let p = SimPath::new("slow", "f.bin");
        // write goes through write_bucket (fast) and caches the file
        s.write(&p, &vec![1u8; 200_000]).unwrap();
        let t0 = std::time::Instant::now();
        s.read(&p).unwrap(); // cache hit
        assert!(t0.elapsed().as_secs_f64() < 0.05);
        s.drop_caches();
        let t0 = std::time::Instant::now();
        s.read(&p).unwrap(); // cold: 200 KB at 1 MB/s ≈ 0.2 s
        assert!(t0.elapsed().as_secs_f64() > 0.1);
    }
}
