//! IOR-style raw bandwidth probe (§IV, Table I).
//!
//! The paper establishes device upper bounds by reading/writing a 5 GB
//! file six times per device (first run is warm-up and discarded),
//! reporting the **median** bandwidth, with caches dropped between
//! runs.  This module reproduces that protocol against the simulated
//! devices; the benchmark binary scales the file size down (the token
//! bucket makes bandwidth size-independent beyond the burst window).

use anyhow::Result;

use super::sim::StorageSim;
use crate::metrics::median;
use crate::util::bytes::mb_per_sec;

/// One device row of Table I.
#[derive(Debug, Clone)]
pub struct IorRow {
    pub device: String,
    pub max_read_mbs: f64,
    pub max_write_mbs: f64,
}

/// IOR protocol parameters.
#[derive(Debug, Clone)]
pub struct IorConfig {
    /// Transfer size per repetition (paper: 5 GB).
    pub file_bytes: u64,
    /// Total repetitions including the discarded warm-up (paper: 6).
    pub reps: usize,
}

impl Default for IorConfig {
    fn default() -> Self {
        IorConfig { file_bytes: 5 * 1000 * 1000 * 1000, reps: 6 }
    }
}

/// Run the IOR protocol on one device; returns its Table I row.
pub fn run_device(sim: &StorageSim, device: &str, cfg: &IorConfig)
    -> Result<IorRow>
{
    // Pacing-only probes: IOR measures the device's bandwidth
    // envelope; routing the probe through backing storage would cap
    // fast simulated devices at the *host's* disk speed instead of
    // the modelled one (see StorageSim::probe_read).
    let mut write_bw = Vec::new();
    let mut read_bw = Vec::new();
    for rep in 0..cfg.reps {
        sim.drop_caches(); // paper: caches dropped before the tests
        let t0 = std::time::Instant::now();
        sim.probe_write(device, cfg.file_bytes)?;
        let w = mb_per_sec(cfg.file_bytes, t0.elapsed().as_secs_f64());

        sim.drop_caches();
        let t0 = std::time::Instant::now();
        sim.probe_read(device, cfg.file_bytes)?;
        let r = mb_per_sec(cfg.file_bytes, t0.elapsed().as_secs_f64());

        if rep > 0 {
            // "The execution run is for warm up and the result is
            // discarded."
            write_bw.push(w);
            read_bw.push(r);
        }
    }
    Ok(IorRow {
        device: device.to_string(),
        max_read_mbs: median(&mut read_bw),
        max_write_mbs: median(&mut write_bw),
    })
}

/// Run the protocol over every device in the sim.
pub fn run_all(sim: &StorageSim, cfg: &IorConfig) -> Result<Vec<IorRow>> {
    sim.device_names()
        .iter()
        .map(|d| run_device(sim, d, cfg))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::device::DeviceModel;

    #[test]
    fn measured_bandwidth_tracks_model() {
        // A 200 MB/s read / 100 MB/s write device, accelerated 4x,
        // probed with 64 MB: measured must land within ~30 % of model.
        let dir = std::env::temp_dir()
            .join(format!("dlio-ior-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let model = DeviceModel {
            name: "dev".into(),
            read_bw: 200e6,
            write_bw: 100e6,
            read_lat: 0.0,
            write_lat: 0.0,
            channels: 4,
            elevator: vec![(1, 1.0)],
            time_scale: 4.0,
        };
        let sim = StorageSim::cold(dir, vec![model]).unwrap();
        let cfg = IorConfig { file_bytes: 64_000_000, reps: 3 };
        let row = run_device(&sim, "dev", &cfg).unwrap();
        // At 4x time-scale the effective rates are 800/400 MB/s.
        // Pacing-only probes land within ~5 % in isolation; allow 30 %
        // because unit tests run concurrently and inflate sleeps.
        let read_model = 200.0 * 4.0;
        let write_model = 100.0 * 4.0;
        assert!((row.max_read_mbs / read_model - 1.0).abs() < 0.30,
                "read {} vs {}", row.max_read_mbs, read_model);
        assert!((row.max_write_mbs / write_model - 1.0).abs() < 0.30,
                "write {} vs {}", row.max_write_mbs, write_model);
    }

    #[test]
    fn run_all_covers_every_device() {
        let dir = std::env::temp_dir()
            .join(format!("dlio-ior-all-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mk = |n: &str| DeviceModel {
            name: n.into(),
            read_bw: 1e9,
            write_bw: 1e9,
            read_lat: 0.0,
            write_lat: 0.0,
            channels: 4,
            elevator: vec![(1, 1.0)],
            time_scale: 1000.0,
        };
        let sim = StorageSim::cold(dir, vec![mk("a"), mk("b")]).unwrap();
        let rows =
            run_all(&sim, &IorConfig { file_bytes: 1_000_000, reps: 2 })
                .unwrap();
        let names: Vec<_> = rows.iter().map(|r| r.device.as_str()).collect();
        assert_eq!(names, vec!["a", "b"]);
    }
}
