//! IOR-style raw bandwidth probe (§IV, Table I).
//!
//! The paper establishes device upper bounds by reading/writing a 5 GB
//! file six times per device (first run is warm-up and discarded),
//! reporting the **median** bandwidth, with caches dropped between
//! runs.  This module reproduces that protocol against the simulated
//! devices; the benchmark binary scales the file size down (the token
//! bucket makes bandwidth size-independent beyond the burst window).

use anyhow::Result;

use super::sim::StorageSim;
use crate::metrics::median;
use crate::util::bytes::mb_per_sec;

/// One device row of Table I.
#[derive(Debug, Clone)]
pub struct IorRow {
    pub device: String,
    pub max_read_mbs: f64,
    pub max_write_mbs: f64,
}

/// IOR protocol parameters.
#[derive(Debug, Clone)]
pub struct IorConfig {
    /// Transfer size per repetition (paper: 5 GB).
    pub file_bytes: u64,
    /// Total repetitions including the discarded warm-up (paper: 6).
    pub reps: usize,
}

impl Default for IorConfig {
    fn default() -> Self {
        IorConfig { file_bytes: 5 * 1000 * 1000 * 1000, reps: 6 }
    }
}

/// Run the IOR protocol on one device; returns its Table I row.
pub fn run_device(sim: &StorageSim, device: &str, cfg: &IorConfig)
    -> Result<IorRow>
{
    // Pacing-only probes: IOR measures the device's bandwidth
    // envelope; routing the probe through backing storage would cap
    // fast simulated devices at the *host's* disk speed instead of
    // the modelled one (see StorageSim::probe_read).  Durations come
    // from the sim's clock, so the protocol works unchanged in
    // discrete-event time.
    let clock = sim.clock().clone();
    let mut write_bw = Vec::new();
    let mut read_bw = Vec::new();
    for rep in 0..cfg.reps {
        sim.drop_caches(); // paper: caches dropped before the tests
        let t0 = clock.now();
        sim.probe_write(device, cfg.file_bytes)?;
        let w = mb_per_sec(cfg.file_bytes, clock.now() - t0);

        sim.drop_caches();
        let t0 = clock.now();
        sim.probe_read(device, cfg.file_bytes)?;
        let r = mb_per_sec(cfg.file_bytes, clock.now() - t0);

        if rep > 0 {
            // "The execution run is for warm up and the result is
            // discarded."
            write_bw.push(w);
            read_bw.push(r);
        }
    }
    Ok(IorRow {
        device: device.to_string(),
        max_read_mbs: median(&mut read_bw),
        max_write_mbs: median(&mut write_bw),
    })
}

/// Run the protocol over every device in the sim.
pub fn run_all(sim: &StorageSim, cfg: &IorConfig) -> Result<Vec<IorRow>> {
    sim.device_names()
        .iter()
        .map(|d| run_device(sim, d, cfg))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::clock::Clock;
    use crate::storage::device::DeviceModel;
    use crate::storage::engine::QosConfig;

    #[test]
    fn measured_bandwidth_tracks_model() {
        // A 200 MB/s read / 100 MB/s write device, accelerated 4x,
        // probed with 64 MB on a virtual clock: the measured bandwidth
        // is the model's, exactly — each probe costs the bucket debt
        // (bytes minus the burst credit) at the effective rate, and
        // discrete-event time cannot be inflated by a loaded host.
        let dir = std::env::temp_dir()
            .join(format!("dlio-ior-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let model = DeviceModel {
            name: "dev".into(),
            read_bw: 200e6,
            write_bw: 100e6,
            read_lat: 0.0,
            write_lat: 0.0,
            channels: 4,
            elevator: vec![(1, 1.0)],
            time_scale: 4.0,
            lat_tables: None,
        };
        let clock = Clock::virt();
        let sim = StorageSim::cold_with_qos_clock(
            dir,
            vec![model],
            QosConfig::default(),
            clock,
        )
        .unwrap();
        let bytes = 64_000_000u64;
        let row = run_device(&sim, "dev",
                             &IorConfig { file_bytes: bytes, reps: 3 })
            .unwrap();
        // Effective rates at 4x time-scale, and the buckets' burst
        // credit: 2 ms of line rate clamped to [64 KiB, 1 MiB].
        let rate_r = 200e6 * 4.0;
        let rate_w = 100e6 * 4.0;
        let burst_r = (rate_r * 0.002).clamp(65536.0, 1_048_576.0);
        let burst_w = (rate_w * 0.002).clamp(65536.0, 1_048_576.0);
        let expect_r = mb_per_sec(bytes, (bytes as f64 - burst_r) / rate_r);
        let expect_w = mb_per_sec(bytes, (bytes as f64 - burst_w) / rate_w);
        // Sub-µs slack only: per-chunk sleeps quantize to nanoseconds.
        assert!((row.max_read_mbs / expect_r - 1.0).abs() < 1e-4,
                "read {} vs {}", row.max_read_mbs, expect_r);
        assert!((row.max_write_mbs / expect_w - 1.0).abs() < 1e-4,
                "write {} vs {}", row.max_write_mbs, expect_w);
    }

    #[test]
    fn run_all_covers_every_device() {
        let dir = std::env::temp_dir()
            .join(format!("dlio-ior-all-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mk = |n: &str| DeviceModel {
            name: n.into(),
            read_bw: 1e9,
            write_bw: 1e9,
            read_lat: 0.0,
            write_lat: 0.0,
            channels: 4,
            elevator: vec![(1, 1.0)],
            time_scale: 1000.0,
            lat_tables: None,
        };
        let sim = StorageSim::cold(dir, vec![mk("a"), mk("b")]).unwrap();
        let rows =
            run_all(&sim, &IorConfig { file_bytes: 1_000_000, reps: 2 })
                .unwrap();
        let names: Vec<_> = rows.iter().map(|r| r.device.as_str()).collect();
        assert_eq!(names, vec!["a", "b"]);
    }
}
