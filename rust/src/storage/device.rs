//! Simulated storage devices (DESIGN.md §2).
//!
//! The paper's experiments observe exactly one surface of the hardware:
//! *service time of reads/writes as a function of request size and
//! concurrency*.  [`DeviceModel`] reproduces that surface with four
//! ingredients, each grounded in a physical mechanism:
//!
//! * `read_bw` / `write_bw` — aggregate transfer caps (Table I upper
//!   bounds), enforced by a shared [`TokenBucket`] per direction.
//! * `read_lat` / `write_lat` — per-operation setup cost (HDD seek,
//!   SSD/NVMe command latency, Lustre RPC round-trip).  This is what
//!   makes a *single* synchronous stream of small files land far below
//!   the IOR bound — the effect behind Fig. 4's thread scaling.
//! * `channels` — how many requests the device services concurrently
//!   (HDD: 1 head; SSD: a few NAND channels; Optane: deep parallelism;
//!   Lustre: many OSTs).
//! * `elevator` — queue-depth → seek-time-reduction curve.  An HDD
//!   with a deeper queue reorders accesses (elevator scheduling), so
//!   the *effective* per-op latency shrinks with diminishing returns —
//!   this is why the paper's HDD curve flattens past 4 threads.
//!
//! Requests perform *real* file I/O against backing storage and are
//! *paced* with sleeps so that measured bandwidth and scaling match the
//! modelled device.  All byte grants flow through an observer hook,
//! which is how the dstat-style tracer (Figs. 8/10) sees traffic.

use std::sync::{Arc, Mutex, RwLock};
use std::time::Duration;

use anyhow::Result;

use super::clock::{Clock, SimCondvar};
use super::fault::{DeviceHealth, HealthState};

/// Transfer direction, for accounting and tracing.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dir {
    Read,
    Write,
}

/// Byte-grant observer (implemented by `trace::Dstat`).
pub trait IoObserver: Send + Sync {
    fn record(&self, device: &str, dir: Dir, bytes: u64);
}

/// A no-op observer.
pub struct NullObserver;

impl IoObserver for NullObserver {
    fn record(&self, _device: &str, _dir: Dir, _bytes: u64) {}
}

/// Optional per-block-size setup-latency tables (placement-policy-
/// vivarium style device calibration): `(block size bytes, per-op
/// setup latency secs)` control points, sorted by block size.  Lookup
/// interpolates linearly between points and clamps at the ends, so a
/// single-point table degenerates to a constant.  The table replaces
/// only the *setup* term of the service-time model — the bandwidth
/// (transfer) term is unchanged — which is what a migration cost
/// model needs: per-device-pair payoff as a function of block size.
#[derive(Debug, Clone, PartialEq)]
pub struct LatencyTables {
    pub read: Vec<(u64, f64)>,
    pub write: Vec<(u64, f64)>,
}

impl LatencyTables {
    /// Interpolated setup latency at `bytes`; `None` for an empty
    /// point list (callers fall back to the single-point latency).
    pub fn interp(points: &[(u64, f64)], bytes: u64) -> Option<f64> {
        let (first, last) = (points.first()?, points.last()?);
        if bytes <= first.0 {
            return Some(first.1);
        }
        for w in points.windows(2) {
            let ((b0, l0), (b1, l1)) = (w[0], w[1]);
            if bytes <= b1 {
                let t = (bytes - b0) as f64 / (b1 - b0).max(1) as f64;
                return Some(l0 + t * (l1 - l0));
            }
        }
        Some(last.1)
    }
}

/// Static description of a device's performance envelope.
#[derive(Debug, Clone)]
pub struct DeviceModel {
    pub name: String,
    /// Aggregate read bandwidth cap, bytes/s (Table I "Max Read").
    pub read_bw: f64,
    /// Aggregate write bandwidth cap, bytes/s (Table I "Max Write").
    pub write_bw: f64,
    /// Per-operation read setup latency, seconds.
    pub read_lat: f64,
    /// Per-operation write setup latency, seconds.
    pub write_lat: f64,
    /// Requests serviced concurrently; extra requests queue.
    pub channels: usize,
    /// (queue_depth, seek-gain) control points; latency is divided by
    /// the interpolated gain.  `[(1, 1.0)]` disables the effect.
    pub elevator: Vec<(u32, f64)>,
    /// Speed multiplier: 1.0 = modelled speed; >1 runs experiments
    /// proportionally faster while preserving every ratio.
    pub time_scale: f64,
    /// Per-block-size setup-latency tables; `None` keeps the
    /// single-point `read_lat`/`write_lat` model bit-for-bit.
    pub lat_tables: Option<LatencyTables>,
}

impl DeviceModel {
    /// Interpolated elevator gain at queue depth `k`.
    pub fn elevator_gain(&self, k: u32) -> f64 {
        let pts = &self.elevator;
        if pts.is_empty() {
            return 1.0;
        }
        if k <= pts[0].0 {
            return pts[0].1;
        }
        for w in pts.windows(2) {
            let (k0, g0) = w[0];
            let (k1, g1) = w[1];
            if k <= k1 {
                let t = (k - k0) as f64 / (k1 - k0) as f64;
                return g0 + t * (g1 - g0);
            }
        }
        pts[pts.len() - 1].1
    }

    /// Per-op setup latency for a `bytes`-sized access: interpolated
    /// from the per-block-size table when one is present, otherwise
    /// the single-point `read_lat`/`write_lat` (bit-compatible for
    /// every pre-existing profile).
    pub fn lat_for(&self, dir: Dir, bytes: u64) -> f64 {
        let (fixed, table) = match dir {
            Dir::Read => {
                (self.read_lat, self.lat_tables.as_ref().map(|t| &t.read))
            }
            Dir::Write => {
                (self.write_lat, self.lat_tables.as_ref().map(|t| &t.write))
            }
        };
        table
            .and_then(|pts| LatencyTables::interp(pts, bytes))
            .unwrap_or(fixed)
    }

    /// Whether a per-block-size table exists for `dir` (callers use
    /// this to avoid paying for a size probe when it cannot matter).
    pub fn has_lat_table(&self, dir: Dir) -> bool {
        match (dir, self.lat_tables.as_ref()) {
            (Dir::Read, Some(t)) => !t.read.is_empty(),
            (Dir::Write, Some(t)) => !t.write.is_empty(),
            _ => false,
        }
    }

    /// Analytic single-request service time (no queueing), seconds.
    /// Used by calibration tests; the live path uses paced sleeps.
    pub fn service_time(&self, dir: Dir, bytes: u64, queue_depth: u32) -> f64 {
        let bw = match dir {
            Dir::Read => self.read_bw,
            Dir::Write => self.write_bw,
        };
        let lat = self.lat_for(dir, bytes);
        (lat / self.elevator_gain(queue_depth) + bytes as f64 / bw)
            / self.time_scale
    }
}

// ---------------------------------------------------------------------------
// Token bucket
// ---------------------------------------------------------------------------

/// Demand-refilled token bucket enforcing an aggregate byte rate.
///
/// No background thread: `take()` refills from elapsed *clock* time
/// (wall or virtual), then either consumes or sleeps on the clock
/// until enough tokens accrue.  Multiple waiters are served in mutex
/// order, which approximates the fair sharing of a device's bandwidth
/// between concurrent streams.
pub struct TokenBucket {
    state: Mutex<BucketState>,
    rate: f64, // tokens (bytes) per second
    burst: f64,
    clock: Clock,
}

struct BucketState {
    tokens: f64,
    /// Clock reading of the last refill, seconds.
    last: f64,
}

impl TokenBucket {
    pub fn new(rate: f64, clock: Clock) -> Self {
        // Allow ~2 ms of burst (clamped to [64 KB, 1 MB]): enough to
        // smooth scheduler jitter, far too little for idle pauses to
        // bank meaningful credit — a multi-MB probe must not ride
        // through on burst tokens even on multi-GB/s scaled devices.
        let burst = (rate * 0.002).clamp(64.0 * 1024.0, 1024.0 * 1024.0);
        Self::with_burst(rate, burst, clock)
    }

    /// A bucket with an explicit burst capacity in bytes (the QoS
    /// per-class rate caps configure their own burst instead of the
    /// device default above).
    pub fn with_burst(rate: f64, burst: f64, clock: Clock) -> Self {
        assert!(rate > 0.0, "rate must be positive");
        let burst = burst.max(1.0);
        TokenBucket {
            state: Mutex::new(BucketState { tokens: burst, last: clock.now() }),
            rate,
            burst,
            clock,
        }
    }

    pub fn rate(&self) -> f64 {
        self.rate
    }

    fn refill(&self, st: &mut BucketState) {
        let now = self.clock.now();
        let dt = (now - st.last).max(0.0);
        st.last = now;
        st.tokens = (st.tokens + dt * self.rate).min(self.burst);
    }

    /// Current balance after a refill, bytes; negative means the
    /// bucket is in debt from a [`charge`](Self::charge).
    pub fn balance(&self) -> f64 {
        let mut st = self.state.lock().unwrap();
        self.refill(&mut st);
        st.tokens
    }

    /// Debt-mode charge: deduct `n` bytes immediately, letting the
    /// balance go negative.  Callers gate dispatch on `balance() > 0`
    /// (or [`until_positive`](Self::until_positive)), so a job of any
    /// size passes once the bucket shows positive budget while the
    /// long-run rate stays capped at `rate` (+ the one-burst,
    /// one-job overshoot inherent to deficit policing).
    pub fn charge(&self, n: u64) {
        let mut st = self.state.lock().unwrap();
        self.refill(&mut st);
        st.tokens -= n as f64;
    }

    /// Atomic check-and-charge: if the balance is positive, charge
    /// `n` (debt-mode, like [`charge`](Self::charge)) and return
    /// `None`; otherwise return how long until it turns positive.
    /// One lock hold for the test *and* the deduction, so concurrent
    /// throttled streams serialize — each admission puts the bucket
    /// in debt before the next waiter's check, keeping the
    /// short-window overshoot at one job, not one job per waiter.
    pub fn try_charge(&self, n: u64) -> Option<Duration> {
        let mut st = self.state.lock().unwrap();
        self.refill(&mut st);
        if st.tokens > 0.0 {
            st.tokens -= n as f64;
            None
        } else {
            Some(Duration::from_secs_f64(
                ((1.0 - st.tokens) / self.rate).clamp(1e-6, 3600.0),
            ))
        }
    }

    /// How long until the balance turns positive (zero if it already
    /// is) — the scheduler's throttle-wait hint.
    pub fn until_positive(&self) -> Duration {
        let mut st = self.state.lock().unwrap();
        self.refill(&mut st);
        if st.tokens > 0.0 {
            Duration::ZERO
        } else {
            // Wait until one byte of budget accrues; clamped so a
            // pathological rate can never produce an unrepresentable
            // Duration.
            Duration::from_secs_f64(
                ((1.0 - st.tokens) / self.rate).clamp(1e-6, 3600.0),
            )
        }
    }

    /// Block until `n` bytes of budget are available, then consume.
    pub fn take(&self, n: u64) {
        self.take_with_credit(n, 0.0)
    }

    /// Like [`take`](Self::take), but `credit` seconds of already-
    /// elapsed real time are converted to byte budget first.  The
    /// device simulator uses this to charge the *real* backing-file
    /// I/O against the modelled service time, so total service is
    /// max(modelled, real) rather than their sum.
    pub fn take_with_credit(&self, n: u64, credit: f64) {
        let mut need = n as f64 - credit.max(0.0) * self.rate;
        if need <= 0.0 {
            return;
        }
        while need > 0.0 {
            let wait;
            {
                let mut st = self.state.lock().unwrap();
                self.refill(&mut st);
                if st.tokens >= need {
                    st.tokens -= need;
                    return;
                }
                // Consume what is there and wait for the rest.
                need -= st.tokens;
                st.tokens = 0.0;
                wait = need / self.rate;
            }
            // In wall mode, cap individual sleeps so concurrent takers
            // interleave; a virtual sleep is exact and free, so one
            // event covers the whole wait.
            let wait = if self.clock.is_virtual() { wait } else { wait.min(0.05) };
            self.clock.sleep_secs(wait);
        }
    }

    /// The clock this bucket refills against.
    pub fn clock(&self) -> &Clock {
        &self.clock
    }
}

// ---------------------------------------------------------------------------
// Live device
// ---------------------------------------------------------------------------

struct ChannelGate {
    lock: Mutex<GateState>,
    cv: SimCondvar,
}

struct GateState {
    in_service: usize,
    /// Total requests either in service or waiting — the queue depth
    /// the elevator model sees.
    depth: u32,
    /// Deepest queue ever observed.  Updated on *every* entry —
    /// including per-chunk stream entries that bypass the engine's
    /// submit paths — so depth bursts that drain between submits are
    /// still recorded (the engine folds this into
    /// `EngineDeviceStats::max_queue_depth`).
    peak_depth: u32,
}

/// Runtime state for one simulated device.
pub struct Device {
    pub model: DeviceModel,
    read_bucket: TokenBucket,
    write_bucket: TokenBucket,
    gate: ChannelGate,
    observer: Arc<dyn IoObserver>,
    clock: Clock,
    /// Armed fault schedule (the health seam, DESIGN.md §15): `None`
    /// — the overwhelmingly common case — means permanently healthy.
    health: RwLock<Option<Arc<DeviceHealth>>>,
}

/// Transfers are paced in chunks so no stream monopolizes the bucket
/// and the tracer sees smooth per-interval traffic.
const CHUNK: u64 = 256 * 1024;

impl Device {
    pub fn new(model: DeviceModel, observer: Arc<dyn IoObserver>) -> Self {
        Self::with_clock(model, observer, Clock::wall())
    }

    /// A device whose pacing, latency phases and bucket refills all run
    /// against `clock`.  Every component of one simulation must share
    /// the same clock.
    pub fn with_clock(
        model: DeviceModel,
        observer: Arc<dyn IoObserver>,
        clock: Clock,
    ) -> Self {
        let ts = model.time_scale;
        assert!(ts > 0.0, "time_scale must be positive");
        Device {
            read_bucket: TokenBucket::new(model.read_bw * ts, clock.clone()),
            write_bucket: TokenBucket::new(model.write_bw * ts, clock.clone()),
            gate: ChannelGate {
                lock: Mutex::new(GateState {
                    in_service: 0,
                    depth: 0,
                    peak_depth: 0,
                }),
                cv: SimCondvar::new(),
            },
            observer,
            model,
            clock,
            health: RwLock::new(None),
        }
    }

    pub fn name(&self) -> &str {
        &self.model.name
    }

    /// Arm (or clear) an injected fault schedule.  Every service path
    /// consults it from here on; `None` restores permanent health.
    pub fn set_health(&self, health: Option<Arc<DeviceHealth>>) {
        *self.health.write().unwrap() = health;
    }

    /// The armed fault schedule, if any.
    pub fn health(&self) -> Option<Arc<DeviceHealth>> {
        self.health.read().unwrap().clone()
    }

    /// State-machine position right now (healthy without a schedule).
    pub fn health_state(&self) -> HealthState {
        match self.health.read().unwrap().as_ref() {
            None => HealthState::Healthy,
            Some(h) => h.state_at(self.clock.now()),
        }
    }

    /// Whether any degradation (state, transient errors, or slowdown)
    /// is active right now — the hierarchy migrator's pause predicate.
    pub fn degraded(&self) -> bool {
        match self.health.read().unwrap().as_ref() {
            None => false,
            Some(h) => h.degraded_at(self.clock.now()),
        }
    }

    /// Admission gate for one request in `dir`: `Err` when the armed
    /// fault schedule denies it (offline, read-only write, or a
    /// transient-error draw).  Healthy devices pay one uncontended
    /// read-lock.
    pub fn fault_gate(&self, dir: Dir) -> Result<()> {
        match self.health.read().unwrap().as_ref() {
            None => Ok(()),
            Some(h) => h.admit(&self.model.name, dir, self.clock.now()),
        }
    }

    /// Current latency/transfer multiplier from the fault schedule
    /// (1.0 when healthy).
    fn fault_slow_factor(&self) -> f64 {
        match self.health.read().unwrap().as_ref() {
            None => 1.0,
            Some(h) => h.slow_factor_at(self.clock.now()),
        }
    }

    /// The clock this device paces against.
    pub fn clock(&self) -> &Clock {
        &self.clock
    }

    /// Join the device queue: the request becomes visible to the
    /// elevator model (queue depth) without claiming a service channel
    /// yet.  Returns the queue depth at entry — callers pass it to
    /// [`service_begin`](Self::service_begin) so a request co-queued
    /// in a deep burst keeps the burst's elevator gain even if the
    /// queue has partially drained by the time it is serviced (the
    /// NCQ batch semantics: one sweep services the co-queued set).
    /// Balanced by [`service_end`](Self::service_end) (after service)
    /// or [`queue_leave`](Self::queue_leave) (cancelled).
    ///
    /// The engine (`super::engine`) calls this at submit time so
    /// queued-but-unserviced requests deepen the queue exactly like
    /// blocked caller threads used to.
    pub fn queue_enter(&self) -> u32 {
        let mut g = self.gate.lock.lock().unwrap();
        g.depth += 1;
        if g.depth > g.peak_depth {
            g.peak_depth = g.depth;
        }
        g.depth
    }

    /// Leave the queue without having claimed a channel (cancelled /
    /// shut-down request).
    pub fn queue_leave(&self) {
        let mut g = self.gate.lock.lock().unwrap();
        g.depth -= 1;
        drop(g);
        self.gate.cv.notify_one(&self.clock);
    }

    /// Claim a service channel (blocks while all `channels` are busy).
    /// Returns the queue depth the elevator model sees: the current
    /// depth or `enqueue_depth` (from
    /// [`queue_enter`](Self::queue_enter)), whichever is deeper.
    pub fn service_begin(&self, enqueue_depth: u32) -> u32 {
        let mut g = self.gate.lock.lock().unwrap();
        while g.in_service >= self.model.channels.max(1) {
            g = self.gate.cv.wait(&self.clock, &self.gate.lock, g);
        }
        g.in_service += 1;
        g.depth.max(enqueue_depth)
    }

    /// Release the service channel and leave the queue.
    pub fn service_end(&self) {
        {
            let mut g = self.gate.lock.lock().unwrap();
            g.in_service -= 1;
            g.depth -= 1;
        }
        self.gate.cv.notify_one(&self.clock);
    }

    /// Sleep the latency phase (seek / command / RPC) for one request
    /// at queue depth `depth`.  An active latency-spike fault
    /// multiplies the phase.  `bytes = 0` clamps a per-block-size
    /// table to its smallest point (and is exact for table-less
    /// models), so callers without a size in hand stay well-defined.
    pub fn latency_phase_sized(&self, dir: Dir, depth: u32, bytes: u64) {
        let lat = self.model.lat_for(dir, bytes)
            / self.model.elevator_gain(depth)
            / self.model.time_scale
            * self.fault_slow_factor();
        self.clock.sleep_secs(lat);
    }

    /// [`latency_phase_sized`](Self::latency_phase_sized) without a
    /// size hint (streaming chunk paths, size-oblivious callers).
    pub fn latency_phase(&self, dir: Dir, depth: u32) {
        self.latency_phase_sized(dir, depth, 0);
    }

    /// Pace `bytes` through the direction's bandwidth bucket, crediting
    /// `credit` seconds of already-elapsed real I/O, and record the
    /// grant with the observer.  One call = one tracer grant; callers
    /// chunk as appropriate.
    pub fn pace(&self, dir: Dir, bytes: u64, credit: f64) {
        if bytes == 0 {
            return;
        }
        let bucket = match dir {
            Dir::Read => &self.read_bucket,
            Dir::Write => &self.write_bucket,
        };
        bucket.take_with_credit(bytes, credit);
        // Latency-spike fault: the window stretches the transfer phase
        // too (the bucket is shared across requests, so the penalty is
        // an extra per-request sleep rather than a rate change — a
        // healthy sibling device keeps its full bandwidth).
        let slow = self.fault_slow_factor();
        if slow > 1.0 {
            let bw = match dir {
                Dir::Read => self.model.read_bw,
                Dir::Write => self.model.write_bw,
            };
            if bw > 0.0 {
                self.clock.sleep_secs(
                    bytes as f64 / bw * (slow - 1.0) / self.model.time_scale,
                );
            }
        }
        self.observer.record(&self.model.name, dir, bytes);
    }

    /// Chunk size for pacing a `bytes`-long transfer: small transfers
    /// pace in 256 KB steps (fine tracer granularity); huge probes use
    /// bigger chunks so per-chunk lock/sleep overhead cannot distort
    /// multi-GB/s devices.
    pub fn pacing_chunk(&self, bytes: u64) -> u64 {
        CHUNK.max(bytes / 64)
    }

    /// Pace a transfer of `bytes` in `dir`, invoking `io` for the real
    /// backing-file operation once the device "positions" (after the
    /// latency phase).  Returns the value produced by `io`, or the
    /// fault-gate error when an armed fault schedule denies the
    /// request (offline, read-only write, transient error draw).
    ///
    /// This is the blocking single-request path, now expressed over the
    /// same primitives the request-level [`IoEngine`]
    /// (`super::engine`) schedules with.
    pub fn transfer<T>(
        &self,
        dir: Dir,
        bytes: u64,
        io: impl FnOnce() -> T,
    ) -> Result<T> {
        // Count the caller as a simulation participant for the span
        // of the transfer: concurrent virtual-mode transfers then
        // overlap their sleeps (the thread-scaling results) instead of
        // serializing against the event heap.
        let _reg = self.clock.enter();

        // --- enter queue + claim a channel ---
        let enq = self.queue_enter();
        let depth = self.service_begin(enq);

        // --- health gate: a denied request fails after claiming (and
        //     releasing) its channel, like a real command error ---
        if let Err(e) = self.fault_gate(dir) {
            self.service_end();
            return Err(e);
        }

        // --- latency phase (seek / command / RPC) ---
        self.latency_phase_sized(dir, depth, bytes);

        // --- real backing I/O (timed: it counts toward service; in
        //     virtual mode the clock cannot advance while we run, so
        //     the credit is zero and service time is purely modelled)
        let io_t0 = self.clock.now();
        let out = io();
        let io_elapsed = self.clock.now() - io_t0;

        // --- transfer phase: paced against the aggregate cap, with
        //     the real I/O time credited so total service time is
        //     max(modelled, real) ---
        let mut credit = io_elapsed;
        let mut remaining = bytes;
        let chunk = self.pacing_chunk(bytes);
        while remaining > 0 {
            let take = remaining.min(chunk);
            self.pace(dir, take, credit);
            credit = 0.0; // credit applies once
            remaining -= take;
        }

        // --- leave ---
        self.service_end();
        Ok(out)
    }

    /// Current queue depth (in-service + waiting).
    pub fn queue_depth(&self) -> u32 {
        self.gate.lock.lock().unwrap().depth
    }

    /// Deepest queue ever observed (monotone: sampled on every entry,
    /// so it can never under-report a burst that drained between
    /// engine submits).
    pub fn peak_queue_depth(&self) -> u32 {
        self.gate.lock.lock().unwrap().peak_depth
    }

    /// Re-seed the peak gauge from the live depth.  Bench and sweep
    /// drivers call this (via `IoEngine::reset_stats`) to bracket a
    /// measured phase after fixture setup; only meaningful at
    /// quiescence.
    pub fn reset_peak_queue_depth(&self) {
        let mut g = self.gate.lock.lock().unwrap();
        g.peak_depth = g.depth;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    fn model(name: &str) -> DeviceModel {
        DeviceModel {
            name: name.into(),
            read_bw: 100e6,
            write_bw: 50e6,
            read_lat: 0.0,
            write_lat: 0.0,
            channels: 4,
            elevator: vec![(1, 1.0)],
            time_scale: 1.0,
            lat_tables: None,
        }
    }

    #[test]
    fn elevator_interpolates() {
        let mut m = model("hdd");
        m.elevator = vec![(1, 1.0), (2, 1.65), (4, 1.95), (8, 2.3)];
        assert!((m.elevator_gain(1) - 1.0).abs() < 1e-9);
        assert!((m.elevator_gain(2) - 1.65).abs() < 1e-9);
        assert!((m.elevator_gain(3) - 1.8).abs() < 1e-9);
        assert!((m.elevator_gain(8) - 2.3).abs() < 1e-9);
        assert!((m.elevator_gain(100) - 2.3).abs() < 1e-9); // clamped
    }

    #[test]
    fn latency_table_interpolates_and_clamps() {
        let mut m = model("tbl");
        m.read_lat = 99.0; // must be ignored once a table exists
        m.lat_tables = Some(LatencyTables {
            read: vec![(4 << 10, 0.001), (64 << 10, 0.002), (1 << 20, 0.010)],
            write: vec![],
        });
        // Below the first point: clamps.
        assert!((m.lat_for(Dir::Read, 0) - 0.001).abs() < 1e-12);
        assert!((m.lat_for(Dir::Read, 1024) - 0.001).abs() < 1e-12);
        // Midpoint of the first segment.
        assert!((m.lat_for(Dir::Read, 34 << 10) - 0.0015).abs() < 1e-12);
        // Exactly on a point.
        assert!((m.lat_for(Dir::Read, 64 << 10) - 0.002).abs() < 1e-12);
        // Above the last point: clamps.
        assert!((m.lat_for(Dir::Read, 1 << 30) - 0.010).abs() < 1e-12);
        // Empty per-direction table falls back to the fixed point.
        m.write_lat = 0.5;
        assert!((m.lat_for(Dir::Write, 1 << 20) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn tableless_model_is_bit_compatible_with_fixed_latency() {
        let mut m = model("fixed");
        m.read_lat = 0.004;
        m.write_lat = 0.006;
        for &bytes in &[0u64, 1 << 10, 1 << 20, 1 << 30] {
            assert_eq!(m.lat_for(Dir::Read, bytes), m.read_lat);
            assert_eq!(m.lat_for(Dir::Write, bytes), m.write_lat);
            let want = (m.read_lat + bytes as f64 / m.read_bw) / m.time_scale;
            assert_eq!(m.service_time(Dir::Read, bytes, 1), want);
        }
        assert!(!m.has_lat_table(Dir::Read));
    }

    #[test]
    fn service_time_scales_with_size() {
        let m = model("d");
        let t1 = m.service_time(Dir::Read, 100_000_000, 1);
        assert!((t1 - 1.0).abs() < 1e-9);
        let t2 = m.service_time(Dir::Write, 50_000_000, 1);
        assert!((t2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn bucket_enforces_rate() {
        // 10 MB at 100 MB/s must take ~0.1 s (minus burst credit).
        let b = TokenBucket::new(100e6, Clock::wall());
        let t0 = Instant::now();
        let mut left = 10_000_000u64;
        while left > 0 {
            let take = left.min(CHUNK);
            b.take(take);
            left -= take;
        }
        let dt = t0.elapsed().as_secs_f64();
        assert!(dt > 0.06, "finished too fast: {dt}");
        assert!(dt < 0.25, "finished too slow: {dt}");
    }

    #[test]
    fn bucket_debt_mode_charges_and_recovers() {
        // 1 MB/s, 10 KB burst: a 100 KB charge rides through on the
        // burst but leaves the bucket deep in debt, and the debt pays
        // off at the configured rate.
        let b = TokenBucket::with_burst(1e6, 10.0 * 1024.0, Clock::wall());
        assert!(b.balance() > 0.0);
        assert_eq!(b.until_positive(), Duration::ZERO);
        b.charge(100 * 1024);
        assert!(b.balance() < 0.0);
        let wait = b.until_positive().as_secs_f64();
        // ~(100 KB - 10 KB burst) / 1 MB/s ≈ 92 ms of debt.
        assert!(wait > 0.05, "debt repaid too fast: {wait}");
        assert!(wait < 0.2, "debt overestimated: {wait}");
        std::thread::sleep(Duration::from_secs_f64(wait));
        assert_eq!(b.until_positive(), Duration::ZERO);
    }

    #[test]
    fn peak_depth_resets_to_live_depth() {
        let d = Device::new(model("rst"), Arc::new(NullObserver));
        d.queue_enter();
        d.queue_enter();
        d.queue_leave();
        assert_eq!(d.peak_queue_depth(), 2);
        d.reset_peak_queue_depth();
        // One request is still live: the gauge re-seeds from it.
        assert_eq!(d.peak_queue_depth(), 1);
        d.queue_leave();
    }

    #[test]
    fn device_transfer_runs_io_and_paces() {
        let d = Device::new(model("x"), Arc::new(NullObserver));
        let t0 = Instant::now();
        let v = d.transfer(Dir::Read, 5_000_000, || 42).unwrap();
        assert_eq!(v, 42);
        let dt = t0.elapsed().as_secs_f64();
        assert!(dt > 0.02, "no pacing applied: {dt}");
    }

    #[test]
    fn channels_limit_concurrency() {
        let mut m = model("one");
        m.channels = 1;
        m.read_lat = 0.03;
        m.read_bw = 1e12; // latency-only device
        let d = Arc::new(Device::new(m, Arc::new(NullObserver)));
        let t0 = Instant::now();
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let d = Arc::clone(&d);
                std::thread::spawn(move || {
                    d.transfer(Dir::Read, 1, || ()).unwrap();
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        // 4 x 30 ms on a single channel must serialize: >= ~120 ms.
        assert!(t0.elapsed().as_secs_f64() > 0.1);
    }

    #[test]
    fn elevator_speeds_up_queued_hdd() {
        // Same workload, elevator on vs off: elevator must be faster.
        let run = |elev: Vec<(u32, f64)>| {
            let m = DeviceModel {
                name: "hdd".into(),
                read_bw: 1e12,
                write_bw: 1e12,
                read_lat: 0.02,
                write_lat: 0.02,
                channels: 1,
                elevator: elev,
                time_scale: 1.0,
                lat_tables: None,
            };
            let d = Arc::new(Device::new(m, Arc::new(NullObserver)));
            let t0 = Instant::now();
            let hs: Vec<_> = (0..6)
                .map(|_| {
                    let d = Arc::clone(&d);
                    std::thread::spawn(move || {
                        d.transfer(Dir::Read, 1, || ()).unwrap()
                    })
                })
                .collect();
            for h in hs {
                h.join().unwrap();
            }
            t0.elapsed().as_secs_f64()
        };
        let flat = run(vec![(1, 1.0)]);
        let elev = run(vec![(1, 1.0), (8, 4.0)]);
        assert!(elev < flat, "elevator {elev} !< flat {flat}");
    }

    #[test]
    fn observer_sees_all_bytes() {
        use std::sync::atomic::{AtomicU64, Ordering};
        struct Counter(AtomicU64);
        impl IoObserver for Counter {
            fn record(&self, _d: &str, _dir: Dir, b: u64) {
                self.0.fetch_add(b, Ordering::SeqCst);
            }
        }
        let obs = Arc::new(Counter(AtomicU64::new(0)));
        let mut m = model("x");
        m.time_scale = 1000.0; // fast test
        let d = Device::new(m, obs.clone());
        d.transfer(Dir::Write, 3_000_000, || ()).unwrap();
        assert_eq!(obs.0.load(Ordering::SeqCst), 3_000_000);
    }

    #[test]
    fn peak_depth_is_monotone_and_survives_drain() {
        let d = Device::new(model("pk"), Arc::new(NullObserver));
        assert_eq!(d.peak_queue_depth(), 0);
        let a = d.queue_enter();
        let b = d.queue_enter();
        let c = d.queue_enter();
        assert_eq!((a, b, c), (1, 2, 3));
        assert_eq!(d.peak_queue_depth(), 3);
        d.queue_leave();
        d.queue_leave();
        d.queue_leave();
        // The queue drained, but the peak is monotone.
        assert_eq!(d.queue_depth(), 0);
        assert_eq!(d.peak_queue_depth(), 3);
        // Re-entering below the old peak does not lower it.
        d.queue_enter();
        assert_eq!(d.peak_queue_depth(), 3);
        d.queue_leave();
    }

    #[test]
    fn bucket_is_exact_under_virtual_clock() {
        // 10 MB at 100 MB/s from a full burst: exactly
        // (bytes - burst) / rate of virtual time, zero wall sleeps.
        let clock = Clock::virt();
        let b = TokenBucket::new(100e6, clock.clone());
        let burst = (100e6f64 * 0.002).clamp(64.0 * 1024.0, 1024.0 * 1024.0);
        let t0 = clock.now();
        let mut left = 10_000_000u64;
        while left > 0 {
            let take = left.min(CHUNK);
            b.take(take);
            left -= take;
        }
        let dt = clock.now() - t0;
        let expect = (10_000_000.0 - burst) / 100e6;
        // Sub-µs slack only: per-chunk sleeps quantize to nanoseconds.
        assert!(
            (dt - expect).abs() < 1e-6,
            "virtual pacing {dt} != expected {expect}"
        );
    }

    #[test]
    fn virtual_transfer_matches_service_time() {
        // Single registered transfer on a virtual clock: elapsed equals
        // the analytic service_time minus the burst credit, exactly.
        let clock = Clock::virt();
        let mut m = model("v");
        m.read_lat = 0.004;
        let d = Device::with_clock(m.clone(), Arc::new(NullObserver), clock.clone());
        let bytes = 8_000_000u64;
        let burst = (m.read_bw * 0.002).clamp(64.0 * 1024.0, 1024.0 * 1024.0);
        let t0 = clock.now();
        d.transfer(Dir::Read, bytes, || ()).unwrap();
        let dt = clock.now() - t0;
        let expect =
            m.service_time(Dir::Read, bytes, 1) - burst / (m.read_bw * m.time_scale);
        // Sub-µs slack only: per-chunk sleeps quantize to nanoseconds.
        assert!(
            (dt - expect).abs() < 1e-6,
            "virtual transfer {dt} != expected {expect}"
        );
    }

    #[test]
    fn fault_gate_denies_then_recovers_and_slow_stretches_service() {
        use super::super::fault::{DeviceHealth, FaultPhase};
        let clock = Clock::virt();
        let d = Device::with_clock(
            model("flt"),
            Arc::new(NullObserver),
            clock.clone(),
        );
        // Offline for the first virtual second: everything fails.
        d.set_health(Some(Arc::new(DeviceHealth::new(
            vec![FaultPhase::state(0.0, 1.0, HealthState::Offline)],
            clock.now(),
        ))));
        let err = d.transfer(Dir::Read, 1_000, || ()).unwrap_err();
        assert!(err.to_string().contains("offline"), "{err}");
        assert_eq!(d.health_state(), HealthState::Offline);
        assert!(d.degraded());
        {
            let _reg = clock.enter();
            clock.sleep_secs(1.5);
        }
        // Recovered: past the window the same request succeeds.
        assert_eq!(d.health_state(), HealthState::Healthy);
        assert!(!d.degraded());
        d.transfer(Dir::Read, 1_000, || ()).unwrap();

        // Latency spike: the same transfer takes ~slow_factor longer.
        let elapsed = |d: &Device| {
            let t0 = d.clock().now();
            d.transfer(Dir::Read, 4_000_000, || ()).unwrap();
            d.clock().now() - t0
        };
        let healthy = elapsed(&d);
        d.set_health(Some(Arc::new(DeviceHealth::new(
            vec![FaultPhase::slow(0.0, f64::INFINITY, 8.0)],
            clock.now(),
        ))));
        let slowed = elapsed(&d);
        assert!(
            slowed > 4.0 * healthy,
            "slow factor 8 transfer {slowed} !> 4x healthy {healthy}"
        );
        d.set_health(None);
        assert!(!d.degraded());
    }

    #[test]
    fn time_scale_accelerates() {
        let mut m = model("fast");
        m.time_scale = 100.0;
        let d = Device::new(m, Arc::new(NullObserver));
        let t0 = Instant::now();
        d.transfer(Dir::Read, 10_000_000, || ()).unwrap();
        // 0.1 s of modelled time at 100x => ~1 ms wall.
        assert!(t0.elapsed().as_secs_f64() < 0.05);
    }
}
