//! [`StorageHierarchy`]: N ordered storage tiers under one placement
//! abstraction (DESIGN.md §12).
//!
//! The paper's two memory-hierarchy artifacts — the burst buffer's
//! fast→slow checkpoint drain (§III-C) and the page cache the
//! protocol works to defeat (§IV) — are the two ends of the same
//! structure: an ordered list of tiers, each with a capacity and a
//! speed, with *something* deciding where data lands and what moves
//! between them.  This module is that structure, generalized:
//!
//! * a tier is a [`TierSpec`] — either a RAM tier ([`RamTier`]: hits
//!   serve with **no device charge**, the page-cache generalization)
//!   or an engine device with an optional byte capacity;
//! * a [`PlacementPolicy`](super::policy::PlacementPolicy) decides
//!   where reads hit (promotions), where writes land, and what
//!   migrates; the hierarchy owns the mechanics — residency, LRU
//!   recency, capacity pressure, and a single background migrator
//!   executing every move as an engine [`IoClass::Drain`] copy
//!   (tagged with [`with_tier`] so trace events and per-tier stats
//!   rows attribute it);
//! * migrations are grouped and complete strictly FIFO — the
//!   burst-buffer drain ordering, preserved by construction, which is
//!   what lets [`BurstBuffer`](crate::checkpoint::BurstBuffer) be a
//!   thin wrapper over a 2-tier hierarchy.
//!
//! Capacity pressure on a bounded device tier demotes LRU-coldest
//! files to the next device tier down (a multi-stage drain); pressure
//! on the bottom tier is advisory (data is never silently dropped).
//! RAM tiers evict internally (LRU over whole files), exactly the old
//! `PageCache` behaviour — which is now literally this module's
//! [`RamTier`] with a compatibility wrapper.

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{anyhow, Context, Result};

use super::clock::{Clock, SimCondvar};
use super::device::Dir;
use super::engine::{with_origin, with_tier, IoClass};
use super::fault::HealthState;
use super::policy::{PlacementPolicy, TierView};
use super::sim::{PendingRead, SimPath, StorageSim};

// ---------------------------------------------------------------------------
// RAM tier (the page cache, as one tier of the same abstraction)
// ---------------------------------------------------------------------------

struct RamState {
    /// key -> (bytes, lru tick)
    entries: HashMap<String, (u64, u64)>,
    total: u64,
    tick: u64,
    hits: u64,
    misses: u64,
}

/// LRU whole-file memory tier with a byte capacity: a hit serves the
/// read with no device charge; a miss inserts the file and evicts
/// LRU-first until it fits.  `capacity == 0` disables the tier (every
/// access misses).  This is the page-cache model the paper defeats
/// with `fadvise`/`drop_caches` — `PageCache` is a thin wrapper over
/// one of these, and every `TierKind::Ram` tier of a hierarchy is one.
pub struct RamTier {
    capacity: u64,
    state: Mutex<RamState>,
}

impl RamTier {
    pub fn new(capacity: u64) -> RamTier {
        RamTier {
            capacity,
            state: Mutex::new(RamState {
                entries: HashMap::new(),
                total: 0,
                tick: 0,
                hits: 0,
                misses: 0,
            }),
        }
    }

    /// Record an access; returns `true` on hit (no device charge).
    /// A size mismatch (the file was overwritten behind the tier's
    /// back) drops the stale entry and re-learns the new size, so
    /// accounting can never carry a phantom size.
    pub fn access(&self, key: &str, bytes: u64) -> bool {
        if self.capacity == 0 {
            let mut st = self.state.lock().unwrap();
            st.misses += 1;
            return false;
        }
        let mut st = self.state.lock().unwrap();
        st.tick += 1;
        let tick = st.tick;
        let cached_size = st.entries.get(key).map(|&(b, _)| b);
        match cached_size {
            Some(b) if b == bytes => {
                st.entries.get_mut(key).expect("entry present").1 = tick;
                st.hits += 1;
                return true;
            }
            Some(b) => {
                st.entries.remove(key);
                st.total -= b;
            }
            None => {}
        }
        st.misses += 1;
        // Insert (files larger than the tier are not cached).
        if bytes <= self.capacity {
            st.total += bytes;
            st.entries.insert(key.to_string(), (bytes, tick));
            while st.total > self.capacity {
                let victim = st
                    .entries
                    .iter()
                    .min_by_key(|(_, (_, t))| *t)
                    .map(|(k, (b, _))| (k.clone(), *b))
                    .expect("non-empty tier over capacity");
                st.entries.remove(&victim.0);
                st.total -= victim.1;
            }
        }
        false
    }

    /// Is `key` resident (without touching recency or counters)?
    pub fn contains(&self, key: &str) -> bool {
        self.state.lock().unwrap().entries.contains_key(key)
    }

    /// Invalidate one key (fadvise DONTNEED).
    pub fn invalidate(&self, key: &str) {
        let mut st = self.state.lock().unwrap();
        if let Some((b, _)) = st.entries.remove(key) {
            st.total -= b;
        }
    }

    /// Drop everything (`echo 1 > /proc/sys/vm/drop_caches`).
    pub fn drop_all(&self) {
        let mut st = self.state.lock().unwrap();
        st.entries.clear();
        st.total = 0;
    }

    /// (hits, misses) counters.
    pub fn stats(&self) -> (u64, u64) {
        let st = self.state.lock().unwrap();
        (st.hits, st.misses)
    }

    /// Bytes currently resident.
    pub fn resident_bytes(&self) -> u64 {
        self.state.lock().unwrap().total
    }

    /// Files currently resident.
    pub fn resident_keys(&self) -> usize {
        self.state.lock().unwrap().entries.len()
    }
}

// ---------------------------------------------------------------------------
// Specs
// ---------------------------------------------------------------------------

/// What backs one tier.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TierKind {
    /// Memory: hits are free, never a durable home.
    Ram,
    /// An engine device (must exist in the sim).
    Device(String),
}

/// One tier of a hierarchy, fastest first.
#[derive(Debug, Clone)]
pub struct TierSpec {
    /// Display name (tier stats, sweep rows).
    pub name: String,
    pub kind: TierKind,
    /// Byte capacity; 0 = unbounded.  Bounded device tiers demote
    /// LRU-coldest files to the next device tier down; a bounded
    /// *bottom* tier is advisory (nothing below to demote to).
    pub capacity: u64,
    /// Writes landing here are asynchronously drained (copied, source
    /// retained) to the next device tier down — the burst-buffer
    /// write-through pattern.
    pub write_through: bool,
}

impl TierSpec {
    /// A RAM tier of `capacity` bytes.
    pub fn ram(capacity: u64) -> TierSpec {
        TierSpec {
            name: "ram".into(),
            kind: TierKind::Ram,
            capacity,
            write_through: false,
        }
    }

    /// A device tier (capacity 0 = unbounded).
    pub fn device(name: &str, capacity: u64) -> TierSpec {
        TierSpec {
            name: name.into(),
            kind: TierKind::Device(name.into()),
            capacity,
            write_through: false,
        }
    }

    /// An unbounded write-through staging device (burst-buffer fast
    /// tier).
    pub fn write_stage(name: &str) -> TierSpec {
        TierSpec { write_through: true, ..TierSpec::device(name, 0) }
    }

    fn device_name(&self) -> Option<&str> {
        match &self.kind {
            TierKind::Ram => None,
            TierKind::Device(d) => Some(d),
        }
    }
}

/// An ordered (fast → slow) tier list.
#[derive(Debug, Clone)]
pub struct HierarchySpec {
    pub name: String,
    pub tiers: Vec<TierSpec>,
}

impl HierarchySpec {
    pub fn new(name: &str, tiers: Vec<TierSpec>) -> HierarchySpec {
        HierarchySpec { name: name.into(), tiers }
    }

    /// The burst buffer's shape: `fast` staging over a `slow` archive.
    /// Drain groups are enqueued explicitly by the wrapper (not
    /// write-through), preserving the saver's triple granularity.
    pub fn two_tier_bb(fast: &str, slow: &str) -> HierarchySpec {
        HierarchySpec::new(
            &format!("bb:{fast}:{slow}"),
            vec![TierSpec::device(fast, 0), TierSpec::device(slow, 0)],
        )
    }
}

// ---------------------------------------------------------------------------
// Runtime state
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Default)]
struct KeyState {
    bytes: u64,
    /// Bitmask of device tiers holding a copy (RAM membership lives
    /// in the RamTier itself).
    copies: u32,
    /// Overwrite generation: bumped whenever new content is
    /// registered for the key.  A migration whose copy was in flight
    /// across a generation change copied stale bytes — it must not
    /// register its destination (or evict its source).
    gen: u64,
}

#[derive(Default)]
struct TierRt {
    /// Bytes resident on this device tier.
    used: u64,
    /// key -> lru tick (device tiers only).
    recency: HashMap<String, u64>,
    /// Keys with an in-flight demotion away from this tier (excluded
    /// from further victim picks; their bytes discount `used` for the
    /// pressure loop so it terminates).
    evicting: HashSet<String>,
    evicting_bytes: u64,
    /// Reads served by this tier.
    hits: u64,
    /// Migration copies that landed here (drains + promotions +
    /// demotions in).
    migrations_in: u64,
    /// Copies dropped from this tier (demotions away + cleanup).
    evictions: u64,
}

struct HierState {
    policy: Box<dyn PlacementPolicy>,
    keys: HashMap<String, KeyState>,
    tiers: Vec<TierRt>,
    tick: u64,
    total_reads: u64,
}

/// One migration step, as executed by the migrator thread.
#[derive(Debug, Clone)]
struct MigJob {
    key: String,
    bytes: u64,
    from: usize,
    to: usize,
    evict_src: bool,
}

#[derive(Clone)]
struct MigGroup {
    label: u64,
    /// Record `label` in the completed-labels ledger (burst-buffer
    /// drain steps record; internal pressure/policy groups don't).
    record: bool,
    jobs: Vec<MigJob>,
    origin: &'static str,
    /// Dynamic "drop the source copies once drained" switch, read at
    /// execution time (the burst buffer's `set_cleanup_staged`).
    cleanup: Option<Arc<AtomicBool>>,
}

#[derive(Default)]
struct Completed {
    labels: Vec<u64>,
    errors: u64,
    /// Degraded-mode pauses: failed groups requeued (not dropped)
    /// because an endpoint device was faulted at the time.
    paused: u64,
}

/// Poll interval (clock seconds) while waiting out an open-ended
/// degradation window, and the floor for scheduled retries.
const DEGRADED_POLL_SECS: f64 = 0.005;

/// Consecutive degraded-mode retries of one group before the migrator
/// gives up and records a hard failure (bounds the wait when a plan
/// never clears; sources are still never reclaimed on failure).
const MAX_DEGRADED_RETRIES: u32 = 64;

struct MigQueue {
    jobs: Mutex<VecDeque<MigGroup>>,
    available: SimCondvar,
    idle: SimCondvar,
    shutdown: Mutex<bool>,
    completed: Mutex<Completed>,
}

struct HierInner {
    sim: Arc<StorageSim>,
    spec: HierarchySpec,
    /// One RamTier per `TierKind::Ram` entry (same index as spec).
    rams: Vec<Option<RamTier>>,
    state: Mutex<HierState>,
    queue: MigQueue,
    /// The sim's time source; the migrator registers against it so
    /// virtual time cannot advance past an in-flight migration.
    clock: Clock,
}

/// Per-tier stats snapshot ([`StorageHierarchy::stats`]).
#[derive(Debug, Clone)]
pub struct TierStatsSnap {
    pub tier: usize,
    pub name: String,
    /// Backing device (`None` for RAM tiers).
    pub device: Option<String>,
    /// Reads served by this tier.
    pub hits: u64,
    pub resident_bytes: u64,
    pub resident_keys: usize,
    pub migrations_in: u64,
    pub evictions: u64,
}

/// The N-tier hierarchy facade.  All methods are `&self`; share via
/// `Arc`.  Dropping the last handle shuts down and joins the
/// migrator (pending migrations complete first).
pub struct StorageHierarchy {
    inner: Arc<HierInner>,
    migrator: Option<JoinHandle<()>>,
}

impl StorageHierarchy {
    /// Validate `spec` against `sim` and start the migrator.
    pub fn new(
        sim: Arc<StorageSim>,
        spec: HierarchySpec,
        mut policy: Box<dyn PlacementPolicy>,
    ) -> Result<StorageHierarchy> {
        if spec.tiers.is_empty() || spec.tiers.len() > 32 {
            return Err(anyhow!(
                "hierarchy {:?} needs 1..=32 tiers, has {}",
                spec.name,
                spec.tiers.len()
            ));
        }
        let mut rams = Vec::with_capacity(spec.tiers.len());
        let mut models = Vec::with_capacity(spec.tiers.len());
        let mut devices = 0usize;
        for t in &spec.tiers {
            match &t.kind {
                TierKind::Ram => {
                    rams.push(Some(RamTier::new(t.capacity)));
                    models.push(None);
                }
                TierKind::Device(d) => {
                    let dev = sim.device(d).with_context(|| {
                        format!("hierarchy {:?} tier {:?}", spec.name, t.name)
                    })?;
                    devices += 1;
                    rams.push(None);
                    models.push(Some(dev.model.clone()));
                }
            }
        }
        if devices == 0 {
            return Err(anyhow!(
                "hierarchy {:?} has no device tier (RAM tiers cannot be a \
                 durable home)",
                spec.name
            ));
        }
        // Hand cost-aware policies the calibrated per-tier device
        // models (index-aligned with the tier list; None for RAM).
        policy.calibrate(&models);
        let tiers = spec.tiers.iter().map(|_| TierRt::default()).collect();
        let clock = sim.clock().clone();
        let inner = Arc::new(HierInner {
            sim,
            spec,
            rams,
            state: Mutex::new(HierState {
                policy,
                keys: HashMap::new(),
                tiers,
                tick: 0,
                total_reads: 0,
            }),
            queue: MigQueue {
                jobs: Mutex::new(VecDeque::new()),
                available: SimCondvar::new(),
                idle: SimCondvar::new(),
                shutdown: Mutex::new(false),
                completed: Mutex::new(Completed::default()),
            },
            clock,
        });
        let migrator = {
            let inner = Arc::clone(&inner);
            std::thread::Builder::new()
                .name("dlio-hier-migrate".into())
                .spawn(move || migrate_loop(inner))
                .expect("spawn hierarchy migrator")
        };
        Ok(StorageHierarchy { inner, migrator: Some(migrator) })
    }

    pub fn spec(&self) -> &HierarchySpec {
        &self.inner.spec
    }

    pub fn policy_name(&self) -> &'static str {
        self.inner.state.lock().unwrap().policy.name()
    }

    /// The policy's decision counters (promotions / demotions /
    /// rejected-by-cost; zeros for cost-blind policies).
    pub fn policy_decisions(&self) -> super::policy::PolicyDecisions {
        self.inner.state.lock().unwrap().policy.decisions()
    }

    /// Modelled seconds of migration work the policy committed to
    /// (0.0 for cost-blind policies) — the numerator of the sweep's
    /// cost-model-accuracy column.
    pub fn predicted_migration_secs(&self) -> f64 {
        self.inner.state.lock().unwrap().policy.predicted_migration_secs()
    }

    pub fn sim(&self) -> &Arc<StorageSim> {
        &self.inner.sim
    }

    /// Tier index of a device name, if it backs one.
    pub fn tier_of_device(&self, device: &str) -> Option<usize> {
        self.inner
            .spec
            .tiers
            .iter()
            .position(|t| t.device_name() == Some(device))
    }

    /// Backing device of tier `tier` (error for RAM tiers).
    pub fn device_of(&self, tier: usize) -> Result<String> {
        self.inner.device_of(tier)
    }

    /// Where the policy lands fresh writes right now:
    /// `(tier, device)`.
    pub fn write_placement(&self) -> (usize, String) {
        let mut st = self.inner.state.lock().unwrap();
        let views = self.inner.views(&st);
        // Out-of-range / RAM placements from a policy fall back to the
        // first device tier: writes need a durable home.
        let tier = st.policy.place_write("", 0, &views);
        let tier = if self
            .inner
            .spec
            .tiers
            .get(tier)
            .and_then(|t| t.device_name())
            .is_none()
        {
            super::policy::first_device_tier(&views)
        } else {
            tier
        };
        // Degraded-mode routing: a read-only or offline backing
        // device cannot take fresh writes — fall through to the next
        // writable device tier below (wrapping to the tiers above if
        // none).  With every device degraded, keep the policy's
        // placement and let the write surface the injected fault.
        let writable = |t: usize| -> bool {
            self.inner.spec.tiers[t]
                .device_name()
                .and_then(|d| self.inner.sim.device(d).ok())
                .map_or(false, |d| d.health_state().admits(Dir::Write))
        };
        let tier = if writable(tier) {
            tier
        } else {
            ((tier + 1)..self.inner.spec.tiers.len())
                .chain(0..tier)
                .find(|&t| writable(t))
                .unwrap_or(tier)
        };
        let dev = self.inner.spec.tiers[tier]
            .device_name()
            .expect("validated device tier")
            .to_string();
        (tier, dev)
    }

    /// Seed residency for a file that already exists on `tier`'s
    /// backing device (corpus fixtures).
    pub fn register(&self, key: &str, bytes: u64, tier: usize) -> Result<()> {
        let _ = self.inner.device_of(tier)?;
        let mut st = self.inner.state.lock().unwrap();
        self.inner.attach_copy(&mut st, key, bytes, tier);
        Ok(())
    }

    /// Read `key` through the hierarchy under [`IoClass::Ingest`].
    pub fn read_async(&self, key: &str) -> Result<PendingRead> {
        self.read_async_class(key, IoClass::Ingest)
    }

    /// Read `key` wherever it is resident: the fastest tier holding a
    /// copy serves.  RAM hits return [`PendingRead::Ready`] with no
    /// device charge; device reads are engine submissions tagged with
    /// the serving tier.  Unknown keys are auto-registered by probing
    /// the tiers' backing stores (fastest first).  The policy sees
    /// every access and its promotion decisions are executed
    /// asynchronously.
    pub fn read_async_class(
        &self,
        key: &str,
        class: IoClass,
    ) -> Result<PendingRead> {
        enum Serve {
            Ram { backing: SimPath },
            Device { tier: usize, path: SimPath },
        }
        let (serve, jobs) = {
            let mut st = self.inner.state.lock().unwrap();
            let ks = match st.keys.get(key) {
                Some(ks) => ks.clone(),
                None => self.inner.auto_register(&mut st, key)?,
            };
            st.total_reads += 1;
            st.tick += 1;
            let tick = st.tick;
            // Fastest tier holding a copy serves; RAM tiers above it
            // fill on their miss (PageCache read-through semantics).
            let mut serving: Option<(usize, bool)> = None;
            // Fastest resident copy on an *offline* device, kept as a
            // last resort: with every copy offline the read still
            // submits there so the injected fault (not a misleading
            // "no resident copy") surfaces.
            let mut offline_fallback: Option<(usize, bool)> = None;
            for (i, spec) in self.inner.spec.tiers.iter().enumerate() {
                match &spec.kind {
                    TierKind::Ram => {
                        let ram =
                            self.inner.rams[i].as_ref().expect("ram slot");
                        if !ram.access(key, ks.bytes) {
                            continue;
                        }
                        // PR-2 dirty-key guard, at this layer too: a
                        // RAM hit whose backing file has an engine
                        // overwrite in flight must not serve (torn
                        // read); fall through to the device read,
                        // which races like any engine read.
                        let clean = match self.inner.fastest_device_copy(&ks)
                        {
                            None => false,
                            Some(home) => {
                                let p = SimPath::new(
                                    self.inner.device_of(home)?,
                                    key.to_string(),
                                );
                                !self.inner.sim.overwrite_in_flight(&p)
                            }
                        };
                        if clean {
                            serving = Some((i, true));
                            break;
                        }
                        ram.invalidate(key);
                    }
                    TierKind::Device(_) => {
                        if ks.copies & (1 << i) != 0 {
                            // Degraded-mode routing: an offline
                            // backing device cannot serve — fall
                            // through to a lower resident copy.
                            let offline = spec
                                .device_name()
                                .and_then(|d| self.inner.sim.device(d).ok())
                                .map_or(false, |d| {
                                    d.health_state()
                                        == HealthState::Offline
                                });
                            if offline {
                                if offline_fallback.is_none() {
                                    offline_fallback = Some((i, false));
                                }
                                continue;
                            }
                            serving = Some((i, false));
                            break;
                        }
                    }
                }
            }
            let serving = serving.or(offline_fallback);
            let Some((tier, is_ram)) = serving else {
                return Err(anyhow!(
                    "hierarchy {:?}: {key:?} has no resident copy",
                    self.inner.spec.name
                ));
            };
            st.tiers[tier].hits += 1;
            let serve = if is_ram {
                // Data comes from the durable home's backing file,
                // with no device charge.
                let home = self.inner.fastest_device_copy(&ks).ok_or_else(
                    || {
                        anyhow!(
                            "hierarchy {:?}: {key:?} resident only in RAM",
                            self.inner.spec.name
                        )
                    },
                )?;
                Serve::Ram {
                    backing: SimPath::new(
                        self.inner.device_of(home)?,
                        key.to_string(),
                    ),
                }
            } else {
                st.tiers[tier].recency.insert(key.to_string(), tick);
                Serve::Device {
                    tier,
                    path: SimPath::new(
                        self.inner.device_of(tier)?,
                        key.to_string(),
                    ),
                }
            };
            // Policy reaction (promotions), translated to work.
            let views = self.inner.views(&st);
            let migs = st.policy.on_read(key, ks.bytes, tier, &views);
            let jobs = self.inner.plan_migrations(&mut st, migs);
            (serve, jobs)
        };
        // I/O strictly outside the lock.
        if !jobs.is_empty() {
            self.inner.enqueue(MigGroup {
                label: 0,
                record: false,
                jobs,
                origin: "hier-promote",
                cleanup: None,
            });
        }
        match serve {
            Serve::Ram { backing } => {
                let path = self.inner.sim.backing_path(&backing);
                let data = std::fs::read(&path)
                    .with_context(|| format!("ram-tier read {backing}"))?;
                Ok(PendingRead::Ready(data))
            }
            Serve::Device { tier, path } => with_tier(tier as u32, || {
                self.inner.sim.read_async_class(&path, class)
            }),
        }
    }

    /// Blocking read (tests / simple drivers).
    pub fn read(&self, key: &str) -> Result<Vec<u8>> {
        self.read_async(key)?.wait()
    }

    /// Write `key` through the hierarchy: the policy places it on a
    /// device tier, the write pays that tier's device, residency and
    /// write-through drains follow.  Returns the tier written.
    pub fn write_class(
        &self,
        key: &str,
        data: &[u8],
        class: IoClass,
    ) -> Result<usize> {
        let (tier, dev) = self.write_placement();
        let p = SimPath::new(dev, key.to_string());
        with_tier(tier as u32, || self.inner.sim.write_class(&p, data, class))?;
        self.note_written_sized(key, data.len() as u64, tier);
        Ok(tier)
    }

    /// Blocking checkpoint-class write.
    pub fn write(&self, key: &str, data: &[u8]) -> Result<usize> {
        self.write_class(key, data, IoClass::Checkpoint)
    }

    /// Register writes that already happened on `tier`'s device
    /// (routed writers like the saver submit through the sim
    /// themselves, overlapped; sizes are statted from the backing
    /// store).  Triggers write-through drains and capacity pressure.
    pub fn note_written(&self, keys: &[String], tier: usize) -> Result<()> {
        let dev = self.inner.device_of(tier)?;
        for key in keys {
            let bytes = self
                .inner
                .sim
                .file_size(&SimPath::new(dev.clone(), key.clone()))?;
            self.note_written_sized(key, bytes, tier);
        }
        Ok(())
    }

    fn note_written_sized(&self, key: &str, bytes: u64, tier: usize) {
        let jobs = {
            let mut st = self.inner.state.lock().unwrap();
            // Stale copies elsewhere are dropped (an overwrite has one
            // authoritative home again); RAM entries invalidate.
            let stale: Vec<usize> = match st.keys.get(key) {
                None => Vec::new(),
                Some(ks) => (0..self.inner.spec.tiers.len())
                    .filter(|&t| t != tier && ks.copies & (1 << t) != 0)
                    .collect(),
            };
            for t in stale {
                self.inner.drop_copy(&mut st, key, t, true);
            }
            for ram in self.inner.rams.iter().flatten() {
                ram.invalidate(key);
            }
            self.inner.attach_copy(&mut st, key, bytes, tier);
            // New content registered: invalidate any migration whose
            // copy is still in flight (it carries the old bytes).
            if let Some(ks) = st.keys.get_mut(key) {
                ks.gen += 1;
            }
            let views = self.inner.views(&st);
            let mut migs = st.policy.on_write(key, bytes, tier, &views);
            // Write-through staging: drain a copy to the next device
            // tier down (source retained; capacity pressure or a
            // cleanup flag reclaims it).
            if self.inner.spec.tiers[tier].write_through {
                if let Some(below) = self.inner.next_device_below(tier) {
                    migs.push(super::policy::Migration {
                        key: key.to_string(),
                        from: tier,
                        to: below,
                        evict_src: false,
                    });
                }
            }
            let mut jobs = self.inner.plan_migrations(&mut st, migs);
            jobs.extend(self.inner.collect_pressure(&mut st, tier));
            jobs
        };
        if !jobs.is_empty() {
            self.inner.enqueue(MigGroup {
                label: 0,
                record: false,
                jobs,
                origin: "hier-drain",
                cleanup: None,
            });
        }
    }

    /// Enqueue an explicit migration group: copy `keys` from tier
    /// `from` to tier `to`, strictly after every previously enqueued
    /// group (FIFO — the burst buffer's oldest-first drain order).
    /// `label` is recorded in [`completed_labels`] on success; the
    /// optional `cleanup` flag is read at execution time and drops
    /// the source copies once the group has drained.
    ///
    /// [`completed_labels`]: StorageHierarchy::completed_labels
    pub fn enqueue_group(
        &self,
        label: u64,
        keys: Vec<String>,
        from: usize,
        to: usize,
        origin: &'static str,
        cleanup: Option<Arc<AtomicBool>>,
    ) -> Result<()> {
        let _ = self.inner.device_of(from)?;
        let _ = self.inner.device_of(to)?;
        let st = self.inner.state.lock().unwrap();
        let jobs: Vec<MigJob> = keys
            .into_iter()
            .map(|key| {
                let bytes =
                    st.keys.get(&key).map(|ks| ks.bytes).unwrap_or(0);
                MigJob { key, bytes, from, to, evict_src: false }
            })
            .collect();
        drop(st);
        self.inner.enqueue(MigGroup {
            label,
            record: true,
            jobs,
            origin,
            cleanup,
        });
        Ok(())
    }

    /// Is a group with `label` still queued or in flight?  Groups are
    /// popped only after their copies finish, so `true` means the
    /// source files must not be deleted yet (the retention-guard
    /// contract).
    pub fn group_pending(&self, label: u64) -> bool {
        self.inner
            .queue
            .jobs
            .lock()
            .unwrap()
            .iter()
            .any(|g| g.record && g.label == label)
    }

    /// Block until every queued migration has completed.
    pub fn wait_idle(&self) {
        let mut jobs = self.inner.queue.jobs.lock().unwrap();
        while !jobs.is_empty() {
            jobs = self.inner.queue.idle.wait(
                &self.inner.clock,
                &self.inner.queue.jobs,
                jobs,
            );
        }
    }

    /// Labels of recorded groups in completion order (FIFO ⇒ enqueue
    /// order — the burst buffer's oldest-first proof).
    pub fn completed_labels(&self) -> Vec<u64> {
        self.inner.queue.completed.lock().unwrap().labels.clone()
    }

    /// Recorded groups fully migrated.
    pub fn completed_count(&self) -> u64 {
        self.inner.queue.completed.lock().unwrap().labels.len() as u64
    }

    /// Migration copy errors so far (failed groups dropped; a
    /// degraded-mode requeue is a pause, not an error).
    pub fn migration_errors(&self) -> u64 {
        self.inner.queue.completed.lock().unwrap().errors
    }

    /// Degraded-mode migration pauses so far: copy failures answered
    /// by requeueing the group (an endpoint device was faulted) —
    /// the time-to-recover signal of a fault run.
    pub fn migration_pauses(&self) -> u64 {
        self.inner.queue.completed.lock().unwrap().paused
    }

    /// Drop `key`'s copy on `tier` (backing file included); other
    /// tiers' copies survive — the burst buffer's staged-file
    /// retention cleanup.
    pub fn remove_from_tier(&self, key: &str, tier: usize) -> Result<()> {
        let dev = self.inner.device_of(tier)?;
        {
            let mut st = self.inner.state.lock().unwrap();
            self.inner.drop_copy(&mut st, key, tier, true);
            for ram in self.inner.rams.iter().flatten() {
                ram.invalidate(key);
            }
        }
        // Belt and braces: a file written around the hierarchy (no
        // residency record) still gets its backing removed.
        let p = SimPath::new(dev, key.to_string());
        if self.inner.sim.exists(&p) {
            let _ = self.inner.sim.remove(&p);
        }
        Ok(())
    }

    /// Forget `key` everywhere (all backing copies removed).
    pub fn remove(&self, key: &str) -> Result<()> {
        let mut st = self.inner.state.lock().unwrap();
        for t in 0..self.inner.spec.tiers.len() {
            self.inner.drop_copy(&mut st, key, t, true);
        }
        for ram in self.inner.rams.iter().flatten() {
            ram.invalidate(key);
        }
        Ok(())
    }

    /// Does any tier hold `key`?
    pub fn resident(&self, key: &str) -> bool {
        self.inner.state.lock().unwrap().keys.contains_key(key)
    }

    /// Device tiers currently holding `key` (fastest first).
    pub fn tiers_of(&self, key: &str) -> Vec<usize> {
        let st = self.inner.state.lock().unwrap();
        match st.keys.get(key) {
            None => Vec::new(),
            Some(ks) => (0..self.inner.spec.tiers.len())
                .filter(|&t| ks.copies & (1 << t) != 0)
                .collect(),
        }
    }

    /// Total reads served (hit-fraction denominators).
    pub fn total_reads(&self) -> u64 {
        self.inner.state.lock().unwrap().total_reads
    }

    /// Per-tier stats snapshot, fastest first.
    pub fn stats(&self) -> Vec<TierStatsSnap> {
        let st = self.inner.state.lock().unwrap();
        self.inner
            .spec
            .tiers
            .iter()
            .enumerate()
            .map(|(i, spec)| {
                let rt = &st.tiers[i];
                let (hits, resident_bytes, resident_keys) =
                    match &self.inner.rams[i] {
                        Some(ram) => {
                            let (ram_hits, _misses) = ram.stats();
                            (
                                ram_hits,
                                ram.resident_bytes(),
                                ram.resident_keys(),
                            )
                        }
                        None => (rt.hits, rt.used, rt.recency.len()),
                    };
                TierStatsSnap {
                    tier: i,
                    name: spec.name.clone(),
                    device: spec.device_name().map(str::to_string),
                    hits,
                    resident_bytes,
                    resident_keys,
                    migrations_in: rt.migrations_in,
                    evictions: rt.evictions,
                }
            })
            .collect()
    }
}

impl Drop for StorageHierarchy {
    fn drop(&mut self) {
        self.wait_idle();
        *self.inner.queue.shutdown.lock().unwrap() = true;
        self.inner.queue.available.notify_all(&self.inner.clock);
        // If this thread is clock-registered, stand aside so a virtual
        // clock can keep advancing while the migrator drains out.
        let _suspended = self.inner.clock.suspend();
        if let Some(m) = self.migrator.take() {
            let _ = m.join();
        }
    }
}

impl HierInner {
    fn device_of(&self, tier: usize) -> Result<String> {
        self.spec
            .tiers
            .get(tier)
            .and_then(|t| t.device_name())
            .map(str::to_string)
            .ok_or_else(|| {
                anyhow!(
                    "hierarchy {:?}: tier {tier} is not a device tier",
                    self.spec.name
                )
            })
    }

    fn next_device_below(&self, tier: usize) -> Option<usize> {
        ((tier + 1)..self.spec.tiers.len())
            .find(|&i| self.spec.tiers[i].device_name().is_some())
    }

    /// After a migration copy failed: if either endpoint device is
    /// currently degraded, the clock time to retry the group at — the
    /// fault schedule's recovery point when known and finite,
    /// otherwise a short poll from now.  `None` when both endpoints
    /// are healthy (the failure was not fault-induced).
    fn degraded_retry_at(&self, job: &MigJob) -> Option<f64> {
        let now = self.clock.now();
        let mut at: Option<f64> = None;
        for tier in [job.from, job.to] {
            let Some(name) =
                self.spec.tiers.get(tier).and_then(|t| t.device_name())
            else {
                continue;
            };
            let Ok(dev) = self.sim.device(name) else { continue };
            if !dev.degraded() {
                continue;
            }
            let until = dev
                .health()
                .and_then(|h| h.recovered_after())
                .filter(|&t| t > now)
                .unwrap_or(now + DEGRADED_POLL_SECS);
            at = Some(at.map_or(until, |a: f64| a.max(until)));
        }
        at
    }

    fn fastest_device_copy(&self, ks: &KeyState) -> Option<usize> {
        (0..self.spec.tiers.len())
            .find(|&i| ks.copies & (1 << i) != 0)
    }

    fn views(&self, st: &HierState) -> Vec<TierView> {
        self.spec
            .tiers
            .iter()
            .enumerate()
            .map(|(i, t)| TierView {
                name: t.name.clone(),
                is_ram: t.device_name().is_none(),
                capacity: t.capacity,
                used: match &self.rams[i] {
                    Some(ram) => ram.resident_bytes(),
                    None => st.tiers[i].used,
                },
            })
            .collect()
    }

    /// Probe the tiers' backing stores for an unregistered key
    /// (fastest first), registering every copy found.
    fn auto_register(
        &self,
        st: &mut HierState,
        key: &str,
    ) -> Result<KeyState> {
        let mut found = None;
        for (i, spec) in self.spec.tiers.iter().enumerate() {
            let Some(dev) = spec.device_name() else { continue };
            let p = SimPath::new(dev, key.to_string());
            if self.sim.exists(&p) {
                let bytes = self.sim.file_size(&p)?;
                self.attach_copy(st, key, bytes, i);
                found = Some(());
            }
        }
        if found.is_none() {
            return Err(anyhow!(
                "hierarchy {:?}: {key:?} not found on any tier",
                self.spec.name
            ));
        }
        Ok(st.keys.get(key).expect("just registered").clone())
    }

    /// Record a copy of `key` on device tier `tier` (idempotent;
    /// reconciles sizes on overwrite).
    fn attach_copy(
        &self,
        st: &mut HierState,
        key: &str,
        bytes: u64,
        tier: usize,
    ) {
        st.tick += 1;
        let tick = st.tick;
        let ks = st.keys.entry(key.to_string()).or_default();
        let had = ks.copies & (1 << tier) != 0;
        let old = ks.bytes;
        ks.bytes = bytes;
        ks.copies |= 1 << tier;
        let rt = &mut st.tiers[tier];
        if had {
            rt.used = rt.used.saturating_sub(old) + bytes;
        } else {
            rt.used += bytes;
        }
        rt.recency.insert(key.to_string(), tick);
    }

    /// Drop `key`'s copy on `tier`; `remove_backing` deletes the
    /// file.  No-op if no copy there.
    fn drop_copy(
        &self,
        st: &mut HierState,
        key: &str,
        tier: usize,
        remove_backing: bool,
    ) {
        let Some(ks) = st.keys.get_mut(key) else { return };
        if ks.copies & (1 << tier) == 0 {
            return;
        }
        ks.copies &= !(1 << tier);
        let bytes = ks.bytes;
        let gone = ks.copies == 0;
        if gone {
            st.keys.remove(key);
        }
        let rt = &mut st.tiers[tier];
        rt.used = rt.used.saturating_sub(bytes);
        rt.recency.remove(key);
        if rt.evicting.remove(key) {
            rt.evicting_bytes = rt.evicting_bytes.saturating_sub(bytes);
        }
        rt.evictions += 1;
        st.policy.on_remove(key, tier);
        if remove_backing {
            if let Some(dev) = self.spec.tiers[tier].device_name() {
                let p = SimPath::new(dev, key.to_string());
                if self.sim.exists(&p) {
                    let _ = self.sim.remove(&p);
                }
            }
        }
    }

    /// Translate policy migrations into executable jobs: moves into
    /// RAM tiers happen inline (free), device→device moves become
    /// migrator jobs (skipping ones whose destination already holds a
    /// copy).
    fn plan_migrations(
        &self,
        st: &mut HierState,
        migs: Vec<super::policy::Migration>,
    ) -> Vec<MigJob> {
        let mut jobs = Vec::new();
        for m in migs {
            let Some(ks) = st.keys.get(&m.key) else { continue };
            if m.from >= self.spec.tiers.len()
                || m.to >= self.spec.tiers.len()
                || m.from == m.to
            {
                continue;
            }
            let bytes = ks.bytes;
            if let Some(ram) = &self.rams[m.to] {
                // RAM fill: free, inline — but only when not already
                // resident (the read-through fill usually just
                // happened; a second access() would count a spurious
                // hit and corrupt the hit-fraction metric).
                if !ram.contains(&m.key) {
                    ram.access(&m.key, bytes);
                }
                continue;
            }
            if ks.copies & (1 << m.from) == 0 {
                continue; // source copy vanished
            }
            if ks.copies & (1 << m.to) != 0 && !m.evict_src {
                continue; // already there
            }
            // A promotion target may itself be RAM-less but the
            // destination could be over capacity afterwards; the
            // migrator re-runs pressure after each landing.
            jobs.push(MigJob {
                key: m.key,
                bytes,
                from: m.from,
                to: m.to,
                evict_src: m.evict_src,
            });
        }
        jobs
    }

    /// Demote LRU-coldest keys off an over-capacity device tier to
    /// the next device tier down (marking them evicting so the loop
    /// terminates and victims aren't re-picked).
    fn collect_pressure(
        &self,
        st: &mut HierState,
        tier: usize,
    ) -> Vec<MigJob> {
        let spec = &self.spec.tiers[tier];
        if spec.capacity == 0 || spec.device_name().is_none() {
            return Vec::new();
        }
        let Some(below) = self.next_device_below(tier) else {
            // Bottom device tier: capacity is advisory (nothing to
            // demote to; data is never dropped).
            return Vec::new();
        };
        let mut jobs = Vec::new();
        loop {
            let rt = &st.tiers[tier];
            if rt.used.saturating_sub(rt.evicting_bytes) <= spec.capacity {
                break;
            }
            let victim = rt
                .recency
                .iter()
                .filter(|(k, _)| !rt.evicting.contains(*k))
                .min_by(|a, b| a.1.cmp(b.1).then(a.0.cmp(b.0)))
                .map(|(k, _)| k.clone());
            let Some(key) = victim else { break };
            let bytes = st.keys.get(&key).map(|k| k.bytes).unwrap_or(0);
            let rt = &mut st.tiers[tier];
            rt.evicting.insert(key.clone());
            rt.evicting_bytes += bytes;
            jobs.push(MigJob {
                key,
                bytes,
                from: tier,
                to: below,
                evict_src: true,
            });
        }
        jobs
    }

    fn enqueue(&self, group: MigGroup) {
        self.queue.jobs.lock().unwrap().push_back(group);
        self.queue.available.notify_one(&self.clock);
    }

    /// Execute one migration job (called by the migrator thread, no
    /// locks held on entry).  Source eviction here is per-job
    /// (`evict_src`, pressure demotions); the group-level `cleanup`
    /// flag is applied by the migrator only after the WHOLE group
    /// succeeded — a mid-group copy failure must leave every staged
    /// source restorable (the burst buffer's original contract).
    fn execute_migration(
        &self,
        job: &MigJob,
        origin: &'static str,
    ) -> Result<()> {
        let evict = job.evict_src;
        // Snapshot validity without holding the lock across the copy.
        // The generation pins the content the copy will read: if an
        // overwrite lands mid-copy, the copied bytes are stale and
        // must not be registered.
        let (need_copy, gen0) = {
            let mut st = self.state.lock().unwrap();
            match st.keys.get(&job.key) {
                None => {
                    self.clear_evicting(&mut st, job);
                    return Ok(());
                }
                Some(ks) if ks.copies & (1 << job.from) == 0 => {
                    self.clear_evicting(&mut st, job);
                    return Ok(());
                }
                Some(ks) => (ks.copies & (1 << job.to) == 0, ks.gen),
            }
        };
        if need_copy {
            let src =
                SimPath::new(self.device_of(job.from)?, job.key.clone());
            let dst = SimPath::new(self.device_of(job.to)?, job.key.clone());
            // Engine-level chunked pipelined copy under the Drain
            // class, tier-tagged to the destination: trace events and
            // per-tier stats rows attribute the movement.
            let res = with_origin(origin, || {
                with_tier(job.to as u32, || {
                    self.sim.copy_class(&src, &dst, IoClass::Drain)
                })
            });
            if let Err(e) = res {
                let mut st = self.state.lock().unwrap();
                // Roll back the destination: a failed copy may have
                // left a partial backing file, and a later probe
                // (auto_register) would claim it as a valid resident
                // copy — a truncated checkpoint must never become
                // restorable.  Only an unregistered destination is
                // removed; a registered copy there is real data from
                // an overwrite that landed mid-copy.
                let dst_registered =
                    st.keys.get(&job.key).map_or(false, |ks| {
                        ks.copies & (1 << job.to) != 0
                    });
                if !dst_registered {
                    if let Ok(dev) = self.device_of(job.to) {
                        let p = SimPath::new(dev, job.key.clone());
                        if self.sim.exists(&p) {
                            let _ = self.sim.remove(&p);
                        }
                    }
                }
                self.clear_evicting(&mut st, job);
                return Err(e);
            }
        }
        let cascade = {
            let mut st = self.state.lock().unwrap();
            // Still the same content (and source) the copy started
            // from?  An overwrite mid-copy bumps the generation.
            let valid = st.keys.get(&job.key).map_or(false, |ks| {
                ks.gen == gen0 && ks.copies & (1 << job.from) != 0
            });
            if need_copy {
                if valid {
                    let bytes =
                        st.keys.get(&job.key).map(|k| k.bytes).unwrap_or(0);
                    self.attach_copy(&mut st, &job.key, bytes, job.to);
                    st.tiers[job.to].migrations_in += 1;
                } else {
                    // Stale copy: drop the unregistered destination
                    // file instead of registering old bytes as a
                    // valid (and fastest) copy — unless the overwrite
                    // itself already landed new content there.
                    let dst_registered =
                        st.keys.get(&job.key).map_or(false, |ks| {
                            ks.copies & (1 << job.to) != 0
                        });
                    if !dst_registered {
                        if let Ok(dev) = self.device_of(job.to) {
                            let p =
                                SimPath::new(dev, job.key.clone());
                            if self.sim.exists(&p) {
                                let _ = self.sim.remove(&p);
                            }
                        }
                    }
                }
            }
            self.clear_evicting(&mut st, job);
            if evict && valid {
                self.drop_copy(&mut st, &job.key, job.from, true);
            }
            self.collect_pressure(&mut st, job.to)
        };
        if !cascade.is_empty() {
            self.enqueue(MigGroup {
                label: 0,
                record: false,
                jobs: cascade,
                origin: "hier-migrate",
                cleanup: None,
            });
        }
        Ok(())
    }

    fn clear_evicting(&self, st: &mut HierState, job: &MigJob) {
        if !job.evict_src {
            return;
        }
        let rt = &mut st.tiers[job.from];
        if rt.evicting.remove(&job.key) {
            rt.evicting_bytes =
                rt.evicting_bytes.saturating_sub(job.bytes);
        }
    }

    /// Group-atomic cleanup: drop every job's source copy (backing
    /// files included).  Called only once the whole group's copies
    /// have landed.  A job whose destination copy is not registered
    /// (its key was overwritten mid-copy and the migration
    /// invalidated itself) keeps its source — never reclaim the only
    /// remaining copy.
    fn evict_group_sources(&self, group: &MigGroup) {
        let mut st = self.state.lock().unwrap();
        for job in &group.jobs {
            let has_dst = st.keys.get(&job.key).map_or(false, |ks| {
                ks.copies & (1 << job.to) != 0
            });
            if has_dst {
                self.drop_copy(&mut st, &job.key, job.from, true);
            }
        }
    }
}

fn migrate_loop(inner: Arc<HierInner>) {
    let _reg = inner.clock.enter();
    // Consecutive degraded-mode retries of the current front group.
    let mut retries = 0u32;
    loop {
        let group = {
            let mut jobs = inner.queue.jobs.lock().unwrap();
            loop {
                if let Some(g) = jobs.front() {
                    break g.clone();
                }
                if *inner.queue.shutdown.lock().unwrap() {
                    return;
                }
                jobs = inner.queue.available.wait(
                    &inner.clock,
                    &inner.queue.jobs,
                    jobs,
                );
            }
        };
        let mut ok = true;
        let mut retry_at: Option<f64> = None;
        for job in &group.jobs {
            if let Err(e) = inner.execute_migration(job, group.origin) {
                ok = false;
                retry_at = inner
                    .degraded_retry_at(job)
                    .filter(|_| retries < MAX_DEGRADED_RETRIES);
                if retry_at.is_some() {
                    inner.queue.completed.lock().unwrap().paused += 1;
                } else {
                    eprintln!(
                        "[hierarchy {}] migrate {:?} tier {} -> {}: {e:#}",
                        inner.spec.name, job.key, job.from, job.to
                    );
                    inner.queue.completed.lock().unwrap().errors += 1;
                }
                break;
            }
        }
        if let Some(at) = retry_at {
            // Degraded-mode pause: an endpoint tier is faulted.  The
            // group stays at the FRONT of the queue — FIFO order and
            // the retention guard both keep holding — and is retried
            // once the fault schedule says the device recovers.
            // Blocks are requeued, never dropped, while a tier is
            // temporarily down.
            retries += 1;
            let wait =
                (at - inner.clock.now()).max(DEGRADED_POLL_SECS);
            inner.clock.sleep_secs(wait);
            continue;
        }
        retries = 0;
        if ok {
            // Staged sources are reclaimed only after the WHOLE group
            // drained: a mid-group failure leaves every staged file
            // restorable from the source tier (the pre-refactor
            // drain_loop's `if ok { cleanup }` contract).  The flag is
            // sampled AFTER the copies land, also matching the old
            // loop: set_cleanup_staged(true) during an in-flight
            // drain applies to that drain.
            let cleanup = group
                .cleanup
                .as_ref()
                .map_or(false, |f| f.load(Ordering::SeqCst));
            if cleanup {
                inner.evict_group_sources(&group);
            }
            if group.record {
                inner
                    .queue
                    .completed
                    .lock()
                    .unwrap()
                    .labels
                    .push(group.label);
            }
        }
        // Pop the group (lifting any retention-guard veto) and wake
        // wait_idle() callers.
        let mut jobs = inner.queue.jobs.lock().unwrap();
        jobs.pop_front();
        let empty = jobs.is_empty();
        drop(jobs);
        if empty {
            inner.queue.idle.notify_all(&inner.clock);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::device::{DeviceModel, Dir, IoObserver};
    use crate::storage::policy;
    use std::sync::atomic::AtomicU64;

    fn model(name: &str, read_lat: f64) -> DeviceModel {
        DeviceModel {
            name: name.into(),
            read_bw: 1e9,
            write_bw: 1e9,
            read_lat,
            write_lat: 0.0,
            channels: 4,
            elevator: vec![(1, 1.0)],
            time_scale: 1000.0,
            lat_tables: None,
        }
    }

    struct Reads(AtomicU64);
    impl IoObserver for Reads {
        fn record(&self, _device: &str, dir: Dir, bytes: u64) {
            if dir == Dir::Read {
                self.0.fetch_add(bytes, Ordering::SeqCst);
            }
        }
    }

    fn sim_with(
        tag: &str,
        models: Vec<DeviceModel>,
    ) -> (Arc<StorageSim>, Arc<Reads>) {
        let dir = std::env::temp_dir()
            .join(format!("dlio-hier-test-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let obs = Arc::new(Reads(AtomicU64::new(0)));
        let sim = Arc::new(
            StorageSim::new(dir, models, 0, obs.clone()).unwrap(),
        );
        (sim, obs)
    }

    fn two_tier(
        tag: &str,
        cap0: u64,
        policy: Box<dyn PlacementPolicy>,
    ) -> (StorageHierarchy, Arc<StorageSim>, Arc<Reads>) {
        let (sim, obs) =
            sim_with(tag, vec![model("fast", 0.0), model("slow", 0.0)]);
        let spec = HierarchySpec::new(
            "t",
            vec![
                TierSpec::device("fast", cap0),
                TierSpec::device("slow", 0),
            ],
        );
        let h =
            StorageHierarchy::new(Arc::clone(&sim), spec, policy).unwrap();
        (h, sim, obs)
    }

    #[test]
    fn rejects_unknown_devices_and_ram_only_specs() {
        let (sim, _) = sim_with("valid", vec![model("fast", 0.0)]);
        let bad = HierarchySpec::new(
            "bad",
            vec![TierSpec::device("tape", 0)],
        );
        assert!(StorageHierarchy::new(
            Arc::clone(&sim),
            bad,
            Box::new(policy::Noop)
        )
        .is_err());
        let ram_only =
            HierarchySpec::new("ram", vec![TierSpec::ram(1 << 20)]);
        assert!(StorageHierarchy::new(
            Arc::clone(&sim),
            ram_only,
            Box::new(policy::Noop)
        )
        .is_err());
    }

    #[test]
    fn reads_route_to_the_fastest_resident_copy() {
        let (h, sim, _) = two_tier("route", 0, Box::new(policy::Noop));
        // k1 on slow only; k2 on both.
        sim.write(&SimPath::new("slow", "k1"), &[1u8; 100]).unwrap();
        sim.write(&SimPath::new("fast", "k2"), &[2u8; 100]).unwrap();
        sim.write(&SimPath::new("slow", "k2"), &[2u8; 100]).unwrap();
        sim.drop_caches();
        // Auto-registration probes the backing stores.
        assert_eq!(h.read("k1").unwrap(), vec![1u8; 100]);
        assert_eq!(h.read("k2").unwrap(), vec![2u8; 100]);
        assert_eq!(h.tiers_of("k1"), vec![1]);
        assert_eq!(h.tiers_of("k2"), vec![0, 1]);
        let stats = h.stats();
        assert_eq!(stats[0].hits, 1, "k2 must be served by the fast tier");
        assert_eq!(stats[1].hits, 1, "k1 must be served by the slow tier");
        assert!(h.read("missing").is_err());
    }

    #[test]
    fn ram_tier_hit_serves_with_no_device_charge() {
        let (sim, obs) = sim_with("ramhit", vec![model("hdd", 0.0)]);
        let spec = HierarchySpec::new(
            "r",
            vec![TierSpec::ram(1 << 20), TierSpec::device("hdd", 0)],
        );
        let h = StorageHierarchy::new(
            Arc::clone(&sim),
            spec,
            Box::new(policy::Noop),
        )
        .unwrap();
        sim.write(&SimPath::new("hdd", "k"), &[7u8; 2048]).unwrap();
        sim.drop_caches();
        // Cold: device read + RAM fill.
        assert_eq!(h.read("k").unwrap(), vec![7u8; 2048]);
        let cold = obs.0.load(Ordering::SeqCst);
        assert!(cold >= 2048, "cold read must charge the device");
        // Warm: served from the RAM tier, device untouched.
        let pr = h.read_async("k").unwrap();
        assert!(matches!(pr, PendingRead::Ready(_)), "expected a RAM hit");
        assert_eq!(pr.wait().unwrap(), vec![7u8; 2048]);
        assert_eq!(obs.0.load(Ordering::SeqCst), cold, "RAM hit charged");
        let stats = h.stats();
        assert_eq!(stats[0].hits, 1);
        assert_eq!(stats[1].hits, 1);
    }

    #[test]
    fn ram_hit_bypassed_while_overwrite_in_flight() {
        // PR-2 dirty-key guard parity at the hierarchy layer: a RAM
        // hit must not serve a key whose backing file has an engine
        // overwrite in flight — the read falls through to the device.
        let (sim, _) = sim_with("ramtorn", vec![model("hdd", 0.0)]);
        let spec = HierarchySpec::new(
            "r",
            vec![TierSpec::ram(1 << 20), TierSpec::device("hdd", 0)],
        );
        let h = StorageHierarchy::new(
            Arc::clone(&sim),
            spec,
            Box::new(policy::Noop),
        )
        .unwrap();
        sim.write(&SimPath::new("hdd", "k"), &[7u8; 4096]).unwrap();
        let _ = h.read("k").unwrap(); // cold: fills the RAM tier
        assert!(matches!(h.read_async("k").unwrap(), PendingRead::Ready(_)));
        // Streaming overwrite in flight: the key is dirty from here.
        let (mut w, pending) =
            sim.write_stream(&SimPath::new("hdd", "k")).unwrap();
        w.push(&[8u8; 10]).unwrap();
        let pr = h.read_async("k").unwrap();
        assert!(
            matches!(pr, PendingRead::InFlight(_)),
            "RAM tier served a file with an overwrite in flight"
        );
        w.finish().unwrap();
        sim.finish_write(pending).unwrap();
        let _ = pr.wait(); // whatever it raced to see; must not hang
        assert_eq!(h.read("k").unwrap(), vec![8u8; 10]);
    }

    #[test]
    fn writes_land_per_policy_and_write_through_drains_down() {
        let (sim, _) = sim_with(
            "wthrough",
            vec![model("fast", 0.0), model("slow", 0.0)],
        );
        let spec = HierarchySpec::new(
            "bb",
            vec![TierSpec::write_stage("fast"), TierSpec::device("slow", 0)],
        );
        let h = StorageHierarchy::new(
            Arc::clone(&sim),
            spec,
            Box::new(policy::Noop),
        )
        .unwrap();
        assert_eq!(h.write("ck/a", &[3u8; 4096]).unwrap(), 0);
        h.wait_idle();
        // Staged copy retained, drained copy landed below.
        assert_eq!(h.tiers_of("ck/a"), vec![0, 1]);
        assert!(sim.exists(&SimPath::new("fast", "ck/a")));
        assert_eq!(
            sim.read(&SimPath::new("slow", "ck/a")).unwrap(),
            vec![3u8; 4096]
        );
        assert_eq!(h.stats()[1].migrations_in, 1);
    }

    #[test]
    fn lru_capacity_pressure_demotes_coldest_first() {
        // Tier 0 fits two 100-byte files; writing three demotes the
        // least recently used (a, refreshed b stays).
        let (h, sim, _) = two_tier("lru", 250, Box::new(policy::Noop));
        h.write("a", &[1u8; 100]).unwrap();
        h.write("b", &[2u8; 100]).unwrap();
        h.wait_idle();
        // Touch a so b becomes the LRU victim.
        let _ = h.read("a").unwrap();
        h.write("c", &[3u8; 100]).unwrap();
        h.wait_idle();
        assert_eq!(h.tiers_of("b"), vec![1], "b (coldest) demoted");
        assert_eq!(h.tiers_of("a"), vec![0], "a (touched) survives");
        assert_eq!(h.tiers_of("c"), vec![0]);
        assert!(!sim.exists(&SimPath::new("fast", "b")), "demotion moves");
        assert_eq!(sim.read(&SimPath::new("slow", "b")).unwrap(), vec![2u8; 100]);
        let s = h.stats();
        assert_eq!(s[0].evictions, 1);
        assert_eq!(s[1].migrations_in, 1);
        // And the demoted key still reads (from the slow tier).
        assert_eq!(h.read("b").unwrap(), vec![2u8; 100]);
    }

    #[test]
    fn frequency_policy_promotes_hot_keys_into_tier0() {
        let (h, sim, _) = two_tier(
            "freq",
            0,
            Box::new(policy::Frequency::new(3, 0)),
        );
        for i in 0..4u8 {
            sim.write(&SimPath::new("slow", format!("f{i}")), &[i; 64])
                .unwrap();
        }
        sim.drop_caches();
        // Two reads: below threshold, stays slow.
        let _ = h.read("f0").unwrap();
        let _ = h.read("f0").unwrap();
        h.wait_idle();
        assert_eq!(h.tiers_of("f0"), vec![1]);
        // Third read crosses the threshold: promoted (copy, source
        // kept — tier 1 is the durable home).
        let _ = h.read("f0").unwrap();
        h.wait_idle();
        assert_eq!(h.tiers_of("f0"), vec![0, 1]);
        assert!(sim.exists(&SimPath::new("fast", "f0")));
        // Subsequent reads hit tier 0.
        let before = h.stats()[0].hits;
        let _ = h.read("f0").unwrap();
        assert_eq!(h.stats()[0].hits, before + 1);
        // Cold keys never promote.
        let _ = h.read("f1").unwrap();
        h.wait_idle();
        assert_eq!(h.tiers_of("f1"), vec![1]);
    }

    #[test]
    fn grouped_migrations_complete_fifo_with_labels() {
        // The burst-buffer ordering contract at the hierarchy level:
        // N groups enqueued back-to-back complete strictly in order,
        // even when each copy is slow enough to backlog the queue.
        let (sim, _) = sim_with(
            "fifo",
            vec![model("fast", 0.0), {
                let mut m = model("slow", 0.0);
                m.write_lat = 0.005;
                m.time_scale = 1.0;
                m
            }],
        );
        let spec = HierarchySpec::two_tier_bb("fast", "slow");
        let h = StorageHierarchy::new(
            Arc::clone(&sim),
            spec,
            Box::new(policy::Noop),
        )
        .unwrap();
        let labels: Vec<u64> = (1..=5).map(|i| i * 10).collect();
        for &l in &labels {
            let key = format!("ck/m-{l}.data");
            h.write(&key, &vec![l as u8; 512]).unwrap();
            h.enqueue_group(l, vec![key], 0, 1, "bb-drain", None)
                .unwrap();
        }
        assert!(h.group_pending(10) || h.completed_count() > 0);
        h.wait_idle();
        assert_eq!(h.migration_errors(), 0);
        assert_eq!(h.completed_labels(), labels, "drains not oldest-first");
        assert!(!h.group_pending(10));
        for &l in &labels {
            assert!(sim.exists(&SimPath::new(
                "slow",
                format!("ck/m-{l}.data")
            )));
        }
    }

    #[test]
    fn cleanup_flag_drops_staged_copies_after_drain() {
        let (h, sim, _) = two_tier("cleanup", 0, Box::new(policy::Noop));
        let flag = Arc::new(AtomicBool::new(true));
        h.write("ck/x", &[9u8; 256]).unwrap();
        h.enqueue_group(
            1,
            vec!["ck/x".into()],
            0,
            1,
            "bb-drain",
            Some(flag),
        )
        .unwrap();
        h.wait_idle();
        assert_eq!(h.tiers_of("ck/x"), vec![1], "staged copy reclaimed");
        assert!(!sim.exists(&SimPath::new("fast", "ck/x")));
        assert_eq!(h.read("ck/x").unwrap(), vec![9u8; 256]);
    }

    #[test]
    fn remove_from_tier_keeps_other_copies() {
        let (h, sim, _) = two_tier("rmtier", 0, Box::new(policy::Noop));
        h.write("k", &[5u8; 128]).unwrap();
        h.enqueue_group(1, vec!["k".into()], 0, 1, "bb-drain", None)
            .unwrap();
        h.wait_idle();
        assert_eq!(h.tiers_of("k"), vec![0, 1]);
        h.remove_from_tier("k", 0).unwrap();
        assert_eq!(h.tiers_of("k"), vec![1]);
        assert!(!sim.exists(&SimPath::new("fast", "k")));
        assert!(sim.exists(&SimPath::new("slow", "k")));
        h.remove("k").unwrap();
        assert!(!h.resident("k"));
        assert!(!sim.exists(&SimPath::new("slow", "k")));
    }

    #[test]
    fn failed_migration_copy_rolls_back_partial_destination() {
        let (h, sim, _) = two_tier("rollback", 0, Box::new(policy::Noop));
        sim.write(&SimPath::new("fast", "blk"), &[9u8; 200_000])
            .unwrap();
        h.register("blk", 200_000, 0).unwrap();
        // Sabotage the copy: the source backing file disappears, so
        // the drain's chunked read fails after the destination file
        // was already created — the partial-destination crash.  Both
        // devices are healthy, so the migrator records a hard error
        // instead of pausing.
        sim.remove(&SimPath::new("fast", "blk")).unwrap();
        h.enqueue_group(7, vec!["blk".into()], 0, 1, "test-drain", None)
            .unwrap();
        h.wait_idle();
        assert_eq!(h.migration_errors(), 1);
        assert!(h.completed_labels().is_empty());
        // Regression: the failed copy must leave NO destination
        // artifact — neither a residency claim nor a partial backing
        // file a later probe would auto-register as a valid copy.
        assert!(
            !h.tiers_of("blk").contains(&1),
            "failed copy left the block claimed on the destination"
        );
        assert!(
            !sim.exists(&SimPath::new("slow", "blk")),
            "failed copy left a partial destination file"
        );
    }

    #[test]
    fn migrator_pauses_and_requeues_during_device_fault() {
        use crate::storage::fault::FaultPlan;
        let (h, sim, _) = two_tier("pause", 0, Box::new(policy::Noop));
        sim.write(&SimPath::new("fast", "blk"), &[5u8; 100_000])
            .unwrap();
        h.register("blk", 100_000, 0).unwrap();
        // Destination offline for 200 ms of clock time from now: the
        // drain's first copy attempt fails, the group must be
        // requeued (paused), then complete once the fault clears.
        sim.apply_fault_plan(
            &FaultPlan::parse("offline:slow:0:0.2").unwrap(),
        )
        .unwrap();
        h.enqueue_group(3, vec!["blk".into()], 0, 1, "test-drain", None)
            .unwrap();
        h.wait_idle();
        assert_eq!(h.migration_errors(), 0, "pause must not be an error");
        assert!(
            h.migration_pauses() >= 1,
            "fault window saw no migrator pause"
        );
        assert_eq!(h.completed_labels(), vec![3], "block was lost");
        assert!(h.tiers_of("blk").contains(&1));
        assert_eq!(h.read("blk").unwrap(), vec![5u8; 100_000]);
    }

    #[test]
    fn writes_route_around_read_only_tier() {
        use crate::storage::fault::FaultPlan;
        let (h, sim, _) = two_tier("wroute", 0, Box::new(policy::Noop));
        assert_eq!(h.write("a", &[1u8; 64]).unwrap(), 0);
        // Tier 0's device goes read-only: fresh writes fall through
        // to the next device tier down, reads keep serving.
        sim.apply_fault_plan(
            &FaultPlan::parse("read-only:fast").unwrap(),
        )
        .unwrap();
        assert_eq!(h.write("b", &[2u8; 64]).unwrap(), 1);
        assert_eq!(h.tiers_of("b"), vec![1]);
        assert_eq!(h.read("a").unwrap(), vec![1u8; 64]);
        sim.clear_faults();
        assert_eq!(h.write("c", &[3u8; 64]).unwrap(), 0, "no recovery");
    }

    #[test]
    fn reads_fall_through_offline_tier_to_lower_copy() {
        use crate::storage::fault::FaultPlan;
        let (h, sim, _) = two_tier("rroute", 0, Box::new(policy::Noop));
        sim.write(&SimPath::new("fast", "k"), &[4u8; 256]).unwrap();
        sim.write(&SimPath::new("slow", "k"), &[4u8; 256]).unwrap();
        sim.drop_caches();
        assert_eq!(h.read("k").unwrap(), vec![4u8; 256]);
        assert_eq!(h.stats()[0].hits, 1, "healthy: fast tier serves");
        // Fast tier offline: the resident copy below serves instead.
        sim.apply_fault_plan(&FaultPlan::parse("offline:fast").unwrap())
            .unwrap();
        assert_eq!(h.read("k").unwrap(), vec![4u8; 256]);
        assert_eq!(h.stats()[1].hits, 1, "offline tier served a read");
        // Every copy offline: the injected fault surfaces, not a
        // misleading "no resident copy".
        sim.apply_fault_plan(&FaultPlan::parse("offline").unwrap())
            .unwrap();
        let err = h.read("k").unwrap_err().to_string();
        assert!(err.contains("offline"), "unexpected error: {err}");
        sim.clear_faults();
        assert_eq!(h.read("k").unwrap(), vec![4u8; 256]);
    }

    #[test]
    fn cost_aware_swap_survives_mid_migration_device_fault() {
        use crate::storage::fault::FaultPlan;
        // Asymmetric tiers so the cost model prices a real gain: a
        // fast bounded tier 0 over a slow durable home.
        let (sim, _) = sim_with(
            "costfault",
            vec![
                {
                    let mut m = model("fast", 0.1e-3);
                    m.write_lat = 0.1e-3;
                    m
                },
                {
                    let mut m = model("slow", 10e-3);
                    m.write_lat = 10e-3;
                    m.read_bw = 100e6;
                    m.write_bw = 100e6;
                    m
                },
            ],
        );
        let spec = HierarchySpec::new(
            "t",
            vec![
                TierSpec::device("fast", 150_000),
                TierSpec::device("slow", 0),
            ],
        );
        let h = StorageHierarchy::new(
            Arc::clone(&sim),
            spec,
            Box::new(policy::CostAware::new(3, 0)),
        )
        .unwrap();
        // "cold" fills tier 0; "hot" lives on the slow durable home.
        h.write("cold", &[1u8; 100_000]).unwrap();
        h.wait_idle();
        sim.write(&SimPath::new("slow", "hot"), &[2u8; 100_000])
            .unwrap();
        sim.drop_caches();
        // Two reads stay below the consider threshold.
        let _ = h.read("hot").unwrap();
        let _ = h.read("hot").unwrap();
        h.wait_idle();
        assert_eq!(h.tiers_of("hot"), vec![1]);
        // Tier 0's device goes offline for 200 ms of clock time.  The
        // third read (served from the healthy slow tier) trips the
        // bidirectional swap — demote "cold" to make room, promote
        // "hot" — and both copies hit the fault mid-flight: the
        // demotion cannot read its source, the promotion cannot write
        // its destination.
        sim.apply_fault_plan(
            &FaultPlan::parse("offline:fast:0:0.2").unwrap(),
        )
        .unwrap();
        assert_eq!(h.read("hot").unwrap(), vec![2u8; 100_000]);
        h.wait_idle();
        // The fault pauses (requeues) the migrator — never a hard
        // error, never a half-applied swap.  Once the window clears
        // the swap completes exactly as planned.
        assert_eq!(h.migration_errors(), 0, "fault became a hard error");
        assert!(
            h.migration_pauses() >= 1,
            "fault window saw no migrator pause"
        );
        assert_eq!(h.tiers_of("hot"), vec![0, 1], "promotion lost");
        assert_eq!(h.tiers_of("cold"), vec![1], "demotion not applied");
        assert!(sim.exists(&SimPath::new("fast", "hot")));
        assert!(!sim.exists(&SimPath::new("fast", "cold")));
        assert!(sim.exists(&SimPath::new("slow", "cold")));
        assert_eq!(h.read("hot").unwrap(), vec![2u8; 100_000]);
        assert_eq!(h.read("cold").unwrap(), vec![1u8; 100_000]);
        let dec = h.policy_decisions();
        assert_eq!(dec.promotions, 1);
        assert_eq!(dec.demotions, 1);
        assert!(h.predicted_migration_secs() > 0.0);
    }
}
