//! Calibrated device profiles for the paper's two testbeds (§IV).
//!
//! Bandwidth caps come straight from Table I (IOR upper bounds).
//! Latency / channel / elevator parameters are calibrated so that the
//! *derived* small-file thread-scaling ratios match §V-A and §VII:
//!
//! * Blackdog HDD: 1→2 = 1.65x, 1→4 = 1.95x, 1→8 = 2.3x, flattening
//!   past 4 threads (single head, elevator gains).
//! * Blackdog SSD / Optane: ≈2x from 1→2 threads then saturation at
//!   the device cap (latency-bound single stream, internal channels).
//! * Tegner Lustre: ≈7.8x at 8 threads (per-RPC latency dominates a
//!   single synchronous stream; OSTs serve streams independently).
//!
//! The calibration tests at the bottom *prove* the ratios analytically
//! from the queueing model, so profile edits that break the paper's
//! shapes fail the suite.

use super::device::{DeviceModel, Dir, LatencyTables};
use super::engine::QosConfig;
use super::hierarchy::{HierarchySpec, TierSpec};

/// Median file size of the ImageNet-subset corpus (§IV-A): 112 KB.
pub const IMAGENET_MEDIAN_BYTES: u64 = 112 * 1024;
/// Median file size of the Caltech-101-like corpus (§IV-B): ~12 KB.
pub const CALTECH_MEDIAN_BYTES: u64 = 12 * 1024;

/// Blackdog 4 TB HDD (Table I: 163.00 / 133.14 MB/s).
pub fn blackdog_hdd(time_scale: f64) -> DeviceModel {
    DeviceModel {
        name: "hdd".into(),
        read_bw: 163.00e6,
        write_bw: 133.14e6,
        // 7.2k-rpm class seek+rotate for dispersed small files.
        read_lat: 8.0e-3,
        write_lat: 8.0e-3,
        channels: 1, // one actuator
        // Elevator gain ≈ measured scaling (seek-dominated regime).
        elevator: vec![(1, 1.0), (2, 1.70), (4, 2.05), (8, 2.55)],
        time_scale,
        lat_tables: None,
    }
}

/// Blackdog Samsung 850 EVO SATA SSD (Table I: 280.55 / 195.05 MB/s).
pub fn blackdog_ssd(time_scale: f64) -> DeviceModel {
    DeviceModel {
        name: "ssd".into(),
        read_bw: 280.55e6,
        write_bw: 195.05e6,
        // SATA command + FS overhead; calibrated so one stream of
        // 112 KB reads lands at ~half the device cap.
        read_lat: 0.40e-3,
        write_lat: 0.45e-3,
        channels: 4,
        elevator: vec![(1, 1.0)],
        time_scale,
        lat_tables: None,
    }
}

/// Blackdog Intel Optane SSD 900p (Table I: 1603.06 / 511.78 MB/s).
pub fn blackdog_optane(time_scale: f64) -> DeviceModel {
    DeviceModel {
        name: "optane".into(),
        read_bw: 1603.06e6,
        write_bw: 511.78e6,
        // 3D-XPoint: ~10 us media, but the paper's stack (ext4 +
        // synchronous pread) sees ~70 us per op.
        read_lat: 0.070e-3,
        write_lat: 0.030e-3,
        channels: 7,
        elevator: vec![(1, 1.0)],
        time_scale,
        lat_tables: None,
    }
}

/// Tegner Lustre parallel FS (Table I: 1968.618 / 991.914 MB/s).
pub fn tegner_lustre(time_scale: f64) -> DeviceModel {
    DeviceModel {
        name: "lustre".into(),
        read_bw: 1968.618e6,
        write_bw: 991.914e6,
        // Network RPC round-trip per file open+read; files are spread
        // over OSTs so streams scale almost independently (§V-A).
        read_lat: 2.0e-3,
        write_lat: 2.5e-3,
        channels: 32,
        elevator: vec![(1, 1.0)],
        time_scale,
        lat_tables: None,
    }
}

// ---------------------------------------------------------------------------
// Calibrated per-block-size device classes (DESIGN.md §17)
// ---------------------------------------------------------------------------
//
// The paper's four profiles model each device with one (lat, bw)
// point.  The cost-aware placement study needs more: migration payoff
// depends on *block size*, and a single latency point over- or
// under-prices small blocks on every device class.  These presets
// carry per-block-size setup-latency tables (linear interpolation,
// clamped) in the spirit of the vivarium exemplar's `devices.rs` —
// peak rates anchored to datasheet-class hardware (Optane SSC DC
// P4800X ≈ 2517 MB/s, NVMe-class flash ≈ 2903 MB/s, 7.2k SATA HDD
// ≈ 120 MB/s), setup latency growing with block size as command and
// DMA overheads stop amortizing.

/// Optane-class low-latency SSD: near-flat latency over block size,
/// deep internal parallelism.  Per-op setup is microseconds, so small
/// random blocks are almost as cheap per byte as large ones — the
/// tier where migrated-in blocks pay off fastest.
pub fn optane_class(time_scale: f64) -> DeviceModel {
    DeviceModel {
        name: "optane-class".into(),
        read_bw: 2517.0e6,
        write_bw: 2200.0e6,
        read_lat: 10.0e-6,
        write_lat: 12.0e-6,
        channels: 16,
        elevator: vec![(1, 1.0)],
        time_scale,
        lat_tables: Some(LatencyTables {
            read: vec![
                (4 << 10, 10.0e-6),
                (64 << 10, 14.0e-6),
                (1 << 20, 30.0e-6),
                (4 << 20, 60.0e-6),
            ],
            write: vec![
                (4 << 10, 12.0e-6),
                (64 << 10, 16.0e-6),
                (1 << 20, 35.0e-6),
                (4 << 20, 70.0e-6),
            ],
        }),
    }
}

/// NVMe-class flash SSD: comparable peak bandwidth to Optane but an
/// order of magnitude more per-op setup at small blocks (flash read
/// latency + deeper firmware path), narrowing toward large blocks.
pub fn nvme_class(time_scale: f64) -> DeviceModel {
    DeviceModel {
        name: "nvme-class".into(),
        read_bw: 2903.0e6,
        write_bw: 1950.0e6,
        read_lat: 80.0e-6,
        write_lat: 30.0e-6,
        channels: 8,
        elevator: vec![(1, 1.0)],
        time_scale,
        lat_tables: Some(LatencyTables {
            read: vec![
                (4 << 10, 80.0e-6),
                (64 << 10, 95.0e-6),
                (1 << 20, 140.0e-6),
                (4 << 20, 250.0e-6),
            ],
            write: vec![
                (4 << 10, 30.0e-6),
                (64 << 10, 45.0e-6),
                (1 << 20, 90.0e-6),
                (4 << 20, 180.0e-6),
            ],
        }),
    }
}

/// HDD-class 7.2k SATA drive: the seek dominates every block size, so
/// the table is nearly flat in absolute terms but the per-byte cost
/// of small blocks is catastrophic — the tier blocks are demoted to.
pub fn hdd_class(time_scale: f64) -> DeviceModel {
    DeviceModel {
        name: "hdd-class".into(),
        read_bw: 120.0e6,
        write_bw: 110.0e6,
        read_lat: 8.5e-3,
        write_lat: 9.0e-3,
        channels: 1,
        elevator: vec![(1, 1.0), (2, 1.70), (4, 2.05), (8, 2.55)],
        time_scale,
        lat_tables: Some(LatencyTables {
            read: vec![
                (4 << 10, 8.5e-3),
                (64 << 10, 8.6e-3),
                (1 << 20, 9.0e-3),
                (4 << 20, 10.5e-3),
            ],
            write: vec![
                (4 << 10, 9.0e-3),
                (64 << 10, 9.1e-3),
                (1 << 20, 9.5e-3),
                (4 << 20, 11.0e-3),
            ],
        }),
    }
}

/// The device preset names, in `by_name` order — what
/// unknown-profile CLI errors list.  The first four are the paper's
/// single-point testbed profiles; the `*-class` trio carries
/// calibrated per-block-size latency tables.
pub const DEVICE_NAMES: [&str; 7] = [
    "hdd",
    "ssd",
    "optane",
    "lustre",
    "optane-class",
    "nvme-class",
    "hdd-class",
];

/// All device presets, by name.
pub fn by_name(name: &str, time_scale: f64) -> Option<DeviceModel> {
    match name {
        "hdd" => Some(blackdog_hdd(time_scale)),
        "ssd" => Some(blackdog_ssd(time_scale)),
        "optane" => Some(blackdog_optane(time_scale)),
        "lustre" => Some(tegner_lustre(time_scale)),
        "optane-class" => Some(optane_class(time_scale)),
        "nvme-class" => Some(nvme_class(time_scale)),
        "hdd-class" => Some(hdd_class(time_scale)),
        _ => None,
    }
}

/// Named storage-hierarchy presets over the paper's devices
/// (DESIGN.md §12).  Tier-0 capacities are modelled bytes; sweep
/// drivers override them to shape cache-pressure studies.
pub const HIERARCHY_NAMES: [&str; 5] = [
    "blackdog-bb",
    "blackdog-direct-hdd",
    "blackdog-tiered",
    "tegner-lustre+optane",
    "calibrated-tiered",
];

/// Resolve a hierarchy preset by name.  Device names refer to the
/// paper profiles ([`by_name`]); the testbed sim must contain them.
pub fn hierarchy_by_name(name: &str) -> Option<HierarchySpec> {
    match name {
        // §III-C's burst buffer: Optane staging drained to HDD.
        "blackdog-bb" => Some(HierarchySpec::new(
            name,
            vec![TierSpec::write_stage("optane"), TierSpec::device("hdd", 0)],
        )),
        // Direct-to-slow baseline (the gray bar of Fig. 9).
        "blackdog-direct-hdd" => Some(HierarchySpec::new(
            name,
            vec![TierSpec::device("hdd", 0)],
        )),
        // 3-tier Blackdog stack: page-cache RAM over a bounded SSD
        // cache over the HDD corpus home.
        "blackdog-tiered" => Some(HierarchySpec::new(
            name,
            vec![
                TierSpec::ram(256 << 20),
                TierSpec::device("ssd", 1 << 30),
                TierSpec::device("hdd", 0),
            ],
        )),
        // Tegner with a node-local Optane cache in front of Lustre —
        // the tier combination the paper benchmarks separately,
        // composed.
        "tegner-lustre+optane" => Some(HierarchySpec::new(
            name,
            vec![
                TierSpec::device("optane", 512 << 20),
                TierSpec::device("lustre", 0),
            ],
        )),
        // Calibrated per-block-size classes (DESIGN.md §17): the
        // hierarchy the cost model prices exactly, since both tiers
        // carry latency tables.
        "calibrated-tiered" => Some(HierarchySpec::new(
            name,
            vec![
                TierSpec::device("optane-class", 512 << 20),
                TierSpec::device("hdd-class", 0),
            ],
        )),
        _ => None,
    }
}

/// The Blackdog workstation device set.
pub fn blackdog(time_scale: f64) -> Vec<DeviceModel> {
    vec![
        blackdog_hdd(time_scale),
        blackdog_ssd(time_scale),
        blackdog_optane(time_scale),
    ]
}

/// Per-profile ingest p99 queue-wait target for the adaptive QoS
/// controller, **modelled** seconds.  One global ms value makes no
/// sense across device classes: a seek-bound HDD (8 ms per op) can
/// never hold the sub-ms bar a deep-parallel Optane idles under, so
/// the controller would pin the HDD's ingest weight at its ceiling
/// forever (no headroom left to react with) while never engaging on
/// Optane.  Targets sit a small multiple above each device's per-op
/// latency floor — reachable when the device is healthy, exceeded as
/// soon as a checkpoint backlog queues ahead of ingest.
pub fn adaptive_ingest_target(name: &str) -> Option<f64> {
    match name {
        "hdd" => Some(12.0e-3),   // ~1.5x the 8 ms seek floor
        "ssd" => Some(2.0e-3),    // a few SATA command slots
        "optane" => Some(0.5e-3), // deep parallelism: waits ~ 0
        "lustre" => Some(5.0e-3), // ~2 RPC round-trips
        "optane-class" => Some(0.3e-3), // sub-optane per-op floor
        "nvme-class" => Some(1.0e-3),   // flash read latency x ~10
        "hdd-class" => Some(14.0e-3),   // ~1.5x the 9 ms seek floor
        _ => None,
    }
}

/// Adaptive QoS with the per-profile controller targets wired in:
/// every paper device gets its own ingest p99 bar
/// ([`adaptive_ingest_target`]) instead of one global ms value — the
/// CLI's `--adaptive-qos auto`.  Unlisted (custom) devices fall back
/// to a mid-range 5 ms target.
pub fn adaptive_auto() -> QosConfig {
    let mut qos = QosConfig::adaptive(5.0e-3);
    if let Some(a) = &mut qos.adaptive {
        for name in DEVICE_NAMES {
            if let Some(t) = adaptive_ingest_target(name) {
                a.per_device.push((name.to_string(), t));
            }
        }
    }
    qos
}

/// Analytic steady-state ingestion throughput (bytes/s) for `k`
/// synchronous streams of `size`-byte reads — the closed form of the
/// device queueing model, used for calibration and tests.
pub fn analytic_throughput(m: &DeviceModel, dir: Dir, size: u64, k: u32) -> f64 {
    let (lat0, bw) = match dir {
        Dir::Read => (m.read_lat, m.read_bw),
        Dir::Write => (m.write_lat, m.write_bw),
    };
    // Each synchronous stream cycles through latency + transfer; at
    // most `channels` are in service, and the aggregate transfer rate
    // is capped at the device bandwidth:
    //     T(k) = min( min(k, c) * S / (lat/gain(k) + S/bw),  bw )
    let lat = lat0 / m.elevator_gain(k);
    let xfer = size as f64 / bw;
    let served = (k as f64).min(m.channels.max(1) as f64);
    (served * size as f64 / (lat + xfer)).min(bw)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ratio(m: &DeviceModel, size: u64, k: u32) -> f64 {
        analytic_throughput(m, Dir::Read, size, k)
            / analytic_throughput(m, Dir::Read, size, 1)
    }

    #[test]
    fn hdd_scaling_matches_paper_shape() {
        // Paper §VII: 1.65x @2, 1.95x @4, 2.3x @8 for HDD small files.
        let m = blackdog_hdd(1.0);
        let s = IMAGENET_MEDIAN_BYTES;
        let r2 = ratio(&m, s, 2);
        let r4 = ratio(&m, s, 4);
        let r8 = ratio(&m, s, 8);
        assert!((r2 - 1.65).abs() < 0.25, "r2={r2}");
        assert!((r4 - 1.95).abs() < 0.30, "r4={r4}");
        assert!((r8 - 2.3).abs() < 0.35, "r8={r8}");
        // Flattens: gain from 4->8 smaller than 1->2.
        assert!(r8 / r4 < r2);
    }

    #[test]
    fn hdd_8_threads_below_ior_bound() {
        // §V-A: TF bandwidth is "unfavorable" vs IOR even at 8 threads.
        let m = blackdog_hdd(1.0);
        let bw8 = analytic_throughput(&m, Dir::Read, IMAGENET_MEDIAN_BYTES, 8);
        assert!(bw8 < m.read_bw, "bw8={bw8}");
    }

    #[test]
    fn ssd_doubles_then_saturates() {
        // §V-A: "increasing from one to two effectively almost doubles
        // the bandwidth ... particularly visible on fast storage".
        let m = blackdog_ssd(1.0);
        let s = IMAGENET_MEDIAN_BYTES;
        let r2 = ratio(&m, s, 2);
        assert!(r2 > 1.6, "r2={r2}");
        // And saturates at the cap by 8 threads.
        let bw8 = analytic_throughput(&m, Dir::Read, s, 8);
        assert!(bw8 > 0.85 * m.read_bw, "bw8={bw8}");
    }

    #[test]
    fn optane_fastest_blackdog_device() {
        let s = IMAGENET_MEDIAN_BYTES;
        for k in [1, 2, 4, 8] {
            let o = analytic_throughput(&blackdog_optane(1.0), Dir::Read, s, k);
            let d = analytic_throughput(&blackdog_ssd(1.0), Dir::Read, s, k);
            let h = analytic_throughput(&blackdog_hdd(1.0), Dir::Read, s, k);
            assert!(o > d && d > h, "k={k}: {o} {d} {h}");
        }
    }

    #[test]
    fn lustre_scales_to_7_8x() {
        // §VII: "On Tegner, we observed a 7.8x increase of bandwidth
        // when using eight threads."
        let m = tegner_lustre(1.0);
        let r8 = ratio(&m, IMAGENET_MEDIAN_BYTES, 8);
        assert!((r8 - 7.8).abs() < 0.6, "r8={r8}");
    }

    #[test]
    fn lustre_best_scalability_of_all_devices() {
        // §V-A: "scaling on Tegner with Lustre shows the best
        // scalability".
        let s = IMAGENET_MEDIAN_BYTES;
        let rl = ratio(&tegner_lustre(1.0), s, 8);
        for m in blackdog(1.0) {
            assert!(rl > ratio(&m, s, 8), "{}", m.name);
        }
    }

    #[test]
    fn write_bandwidth_ordering_for_checkpoints() {
        // Fig. 9 ordering: optane > ssd > hdd for large writes.
        let big = 64 * 1024 * 1024;
        let o = analytic_throughput(&blackdog_optane(1.0), Dir::Write, big, 1);
        let s = analytic_throughput(&blackdog_ssd(1.0), Dir::Write, big, 1);
        let h = analytic_throughput(&blackdog_hdd(1.0), Dir::Write, big, 1);
        assert!(o > 2.0 * s, "optane {o} vs ssd {s}");
        assert!(s > h, "ssd {s} vs hdd {h}");
    }

    #[test]
    fn ior_large_sequential_hits_table1() {
        // One big sequential stream approaches the Table I cap.
        for m in [blackdog_hdd(1.0), blackdog_ssd(1.0),
                  blackdog_optane(1.0), tegner_lustre(1.0)] {
            let bw = analytic_throughput(&m, Dir::Read, 512 * 1024 * 1024, 1);
            assert!(bw > 0.95 * m.read_bw, "{}: {bw}", m.name);
        }
    }

    #[test]
    fn adaptive_targets_track_device_latency_ordering() {
        // Slower per-op devices get laxer bars (the controller must
        // have reachable targets on every profile).
        let t = |n: &str| adaptive_ingest_target(n).unwrap();
        assert!(t("hdd") > t("lustre"));
        assert!(t("lustre") > t("ssd"));
        assert!(t("ssd") > t("optane"));
        assert!(adaptive_ingest_target("floppy").is_none());
        // Each target clears its device's single-op latency floor.
        for name in ["hdd", "ssd", "optane", "lustre"] {
            let m = by_name(name, 1.0).unwrap();
            assert!(t(name) > m.read_lat, "{name}: unreachable target");
        }
        // adaptive_auto wires every preset through target_for.
        let qos = adaptive_auto();
        let a = qos.adaptive.as_ref().unwrap();
        assert_eq!(a.target_for("hdd"), t("hdd"));
        assert_eq!(a.target_for("optane"), t("optane"));
        assert_eq!(a.target_for("custom-dev"), a.target_ingest_p99);
        assert_eq!(qos.mode_name(), "adaptive");
    }

    #[test]
    fn by_name_roundtrip() {
        for n in DEVICE_NAMES {
            assert_eq!(by_name(n, 1.0).unwrap().name, n);
        }
        assert!(by_name("floppy", 1.0).is_none());
    }

    #[test]
    fn paper_profiles_stay_single_point() {
        // Bit-compatibility guard: the four paper profiles must keep
        // the single-point latency model (every calibration ratio
        // above depends on it).
        for n in ["hdd", "ssd", "optane", "lustre"] {
            assert!(by_name(n, 1.0).unwrap().lat_tables.is_none(), "{n}");
        }
    }

    #[test]
    fn calibrated_classes_interpolate_monotonically() {
        for n in ["optane-class", "nvme-class", "hdd-class"] {
            let m = by_name(n, 1.0).unwrap();
            assert!(m.has_lat_table(Dir::Read), "{n}");
            assert!(m.has_lat_table(Dir::Write), "{n}");
            // Setup latency grows with block size (amortization stops).
            for dir in [Dir::Read, Dir::Write] {
                let mut prev = 0.0;
                for bytes in [4 << 10, 64 << 10, 1 << 20, 4 << 20] {
                    let lat = m.lat_for(dir, bytes);
                    assert!(lat > prev, "{n}: non-monotone at {bytes}");
                    prev = lat;
                }
            }
            // The table's smallest point matches the single-point
            // fallback, so size-oblivious paths (bytes = 0) agree.
            assert_eq!(m.lat_for(Dir::Read, 0), m.read_lat, "{n}");
        }
    }

    #[test]
    fn calibrated_class_ordering_holds_across_block_sizes() {
        // Per-op service time: optane-class < nvme-class < hdd-class
        // at every block size — the gradient the cost model descends.
        let o = optane_class(1.0);
        let n = nvme_class(1.0);
        let h = hdd_class(1.0);
        for bytes in [4 << 10, 64 << 10, 1 << 20, 4 << 20] {
            let so = o.service_time(Dir::Read, bytes, 1);
            let sn = n.service_time(Dir::Read, bytes, 1);
            let sh = h.service_time(Dir::Read, bytes, 1);
            assert!(so < sn, "bytes={bytes}: {so} !< {sn}");
            assert!(sn < sh, "bytes={bytes}: {sn} !< {sh}");
        }
        // And a 4 MiB sequential stream still approaches the peak rate
        // (the snippet-1 calibration anchor: block time ≈ size/peak).
        for m in [&o, &n, &h] {
            let bytes = 4 << 20;
            let floor = bytes as f64 / m.read_bw;
            let svc = m.service_time(Dir::Read, bytes, 1);
            assert!(
                svc < 1.25 * floor + m.lat_for(Dir::Read, bytes),
                "{}: {svc}",
                m.name
            );
        }
    }

    #[test]
    fn hierarchy_presets_resolve_with_known_devices() {
        use crate::storage::hierarchy::TierKind;
        for n in HIERARCHY_NAMES {
            let spec = hierarchy_by_name(n)
                .unwrap_or_else(|| panic!("preset {n} missing"));
            assert_eq!(spec.name, n);
            assert!(!spec.tiers.is_empty());
            let mut devices = 0;
            for t in &spec.tiers {
                if let TierKind::Device(d) = &t.kind {
                    assert!(
                        by_name(d, 1.0).is_some(),
                        "{n}: unknown device {d}"
                    );
                    devices += 1;
                }
            }
            assert!(devices >= 1, "{n}: no device tier");
        }
        assert!(hierarchy_by_name("blackdog-floppy").is_none());
        // The burst-buffer preset drains fast -> slow.
        let bb = hierarchy_by_name("blackdog-bb").unwrap();
        assert!(bb.tiers[0].write_through);
        assert_eq!(bb.tiers.len(), 2);
    }
}
