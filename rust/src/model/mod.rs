//! Model state + training driver on the rust side.
//!
//! The network itself lives in L2 (`python/compile/model.py`, lowered
//! to HLO); this module owns the *state* — parameter tensors, Adam
//! moments, the step counter — initializes it (same He-normal scheme
//! as the python reference), marshals it through the train-step
//! executable, and serializes it for checkpointing.

pub mod params;
pub mod trainer;

pub use params::ModelState;
pub use trainer::Trainer;
