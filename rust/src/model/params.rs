//! Parameter / optimizer state container.

use anyhow::{bail, Result};

use crate::runtime::meta::ProfileMeta;
use crate::util::Rng;

/// Full training state: parameters + Adam first/second moments + step.
#[derive(Debug, Clone)]
pub struct ModelState {
    /// One flat f32 buffer per parameter tensor, in ABI order.
    pub params: Vec<Vec<f32>>,
    pub m: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
    pub step: f32,
}

impl ModelState {
    /// Initialize like `model.init_params`: He-normal kernels
    /// (std = sqrt(2/fan_in)), zero biases, zero moments.
    pub fn init(profile: &ProfileMeta, seed: u64) -> ModelState {
        let mut rng = Rng::new(seed);
        let mut params = Vec::with_capacity(profile.params.len());
        for spec in &profile.params {
            let n = spec.num_elements();
            if spec.is_bias() {
                params.push(vec![0f32; n]);
            } else {
                let std = (2.0 / spec.fan_in() as f64).sqrt();
                params.push(
                    (0..n)
                        .map(|_| (rng.next_normal() * std) as f32)
                        .collect(),
                );
            }
        }
        let zeros: Vec<Vec<f32>> =
            params.iter().map(|p| vec![0f32; p.len()]).collect();
        ModelState { m: zeros.clone(), v: zeros, params, step: 0.0 }
    }

    pub fn num_tensors(&self) -> usize {
        self.params.len()
    }

    pub fn num_values(&self) -> usize {
        self.params.iter().map(Vec::len).sum()
    }

    /// Serialized checkpoint payload size in bytes (the `.data` file).
    pub fn data_bytes(&self) -> u64 {
        (self.num_values() * 3 * 4 + 4) as u64
    }

    /// Stream the checkpoint `.data` payload (`params + m + v + step`,
    /// little-endian f32) through `sink`, one tensor slice at a time.
    /// This is what the saver feeds into the engine's chunked write
    /// stream, so a checkpoint never needs one contiguous
    /// payload-sized buffer.
    ///
    /// Perf note (DESIGN.md §Perf): whole-tensor slice views, not
    /// per-value `to_le_bytes` — checkpoint serialization sits on the
    /// synchronous save path the paper measures, and the naive loop
    /// cost ~10x more than the simulated Optane write it precedes.
    pub fn stream_bytes(
        &self,
        mut sink: impl FnMut(&[u8]) -> Result<()>,
    ) -> Result<()> {
        for group in [&self.params, &self.m, &self.v] {
            for tensor in group {
                // f32 slices are plain little-endian bytes on every
                // supported target; view the raw representation.
                let bytes = unsafe {
                    std::slice::from_raw_parts(
                        tensor.as_ptr() as *const u8,
                        tensor.len() * 4,
                    )
                };
                sink(bytes)?;
            }
        }
        sink(&self.step.to_le_bytes())
    }

    /// Serialize the full `.data` payload into one buffer (tests and
    /// small states; the saver streams instead).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.data_bytes() as usize);
        self.stream_bytes(|bytes| {
            out.extend_from_slice(bytes);
            Ok(())
        })
        .expect("in-memory sink is infallible");
        out
    }

    /// Inverse of [`to_bytes`]; `profile` supplies the tensor shapes.
    pub fn from_bytes(profile: &ProfileMeta, bytes: &[u8])
        -> Result<ModelState>
    {
        let total: usize = profile
            .params
            .iter()
            .map(|s| s.num_elements())
            .sum();
        let want = total * 3 * 4 + 4;
        if bytes.len() != want {
            bail!("checkpoint payload {} bytes, expected {want}",
                  bytes.len());
        }
        let mut offset = 0usize;
        let mut read_group = |bytes: &[u8]| -> Vec<Vec<f32>> {
            profile
                .params
                .iter()
                .map(|spec| {
                    let n = spec.num_elements();
                    // Bulk deserialize (see to_bytes): copy the raw
                    // little-endian block into an f32 vec.
                    let mut t = vec![0f32; n];
                    let src = &bytes[offset..offset + n * 4];
                    unsafe {
                        std::ptr::copy_nonoverlapping(
                            src.as_ptr(),
                            t.as_mut_ptr() as *mut u8,
                            n * 4,
                        );
                    }
                    offset += n * 4;
                    t
                })
                .collect()
        };
        let params = read_group(bytes);
        let m = read_group(bytes);
        let v = read_group(bytes);
        let step =
            f32::from_le_bytes(bytes[offset..offset + 4].try_into().unwrap());
        Ok(ModelState { params, m, v, step })
    }

    /// Consistency check against the profile's shapes.
    pub fn validate(&self, profile: &ProfileMeta) -> Result<()> {
        if self.params.len() != profile.params.len() {
            bail!("tensor count {} != {}", self.params.len(),
                  profile.params.len());
        }
        for (group_name, group) in
            [("params", &self.params), ("m", &self.m), ("v", &self.v)]
        {
            for (t, spec) in group.iter().zip(&profile.params) {
                if t.len() != spec.num_elements() {
                    bail!("{group_name}/{}: {} values, expected {}",
                          spec.name, t.len(), spec.num_elements());
                }
            }
        }
        if !self.step.is_finite() || self.step < 0.0 {
            bail!("bad step counter {}", self.step);
        }
        Ok(())
    }

    /// Max |value| across parameters (divergence guard in tests).
    pub fn max_abs_param(&self) -> f32 {
        self.params
            .iter()
            .flat_map(|t| t.iter())
            .fold(0f32, |a, &b| a.max(b.abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::meta::ParamSpec;

    fn profile() -> ProfileMeta {
        ProfileMeta {
            name: "t".into(),
            input_size: 8,
            num_classes: 4,
            num_params: 2 * 2 * 3 * 2 + 2,
            params: vec![
                ParamSpec { name: "conv1/kernel".into(),
                            shape: vec![2, 2, 3, 2] },
                ParamSpec { name: "conv1/bias".into(), shape: vec![2] },
            ],
        }
    }

    #[test]
    fn init_shapes_and_stats() {
        let p = profile();
        let s = ModelState::init(&p, 1);
        s.validate(&p).unwrap();
        assert_eq!(s.params[0].len(), 24);
        assert_eq!(s.params[1], vec![0.0, 0.0]); // bias zero
        assert!(s.m.iter().all(|t| t.iter().all(|&x| x == 0.0)));
        assert_eq!(s.step, 0.0);
        // Kernel values centred, non-degenerate.
        let mean: f32 =
            s.params[0].iter().sum::<f32>() / s.params[0].len() as f32;
        assert!(mean.abs() < 0.5);
        assert!(s.max_abs_param() > 0.0);
    }

    #[test]
    fn init_deterministic_per_seed() {
        let p = profile();
        assert_eq!(ModelState::init(&p, 7).params,
                   ModelState::init(&p, 7).params);
        assert_ne!(ModelState::init(&p, 7).params,
                   ModelState::init(&p, 8).params);
    }

    #[test]
    fn byte_roundtrip() {
        let p = profile();
        let mut s = ModelState::init(&p, 3);
        s.step = 17.0;
        s.m[0][5] = 0.25;
        let bytes = s.to_bytes();
        assert_eq!(bytes.len() as u64, s.data_bytes());
        let back = ModelState::from_bytes(&p, &bytes).unwrap();
        assert_eq!(back.params, s.params);
        assert_eq!(back.m, s.m);
        assert_eq!(back.step, 17.0);
    }

    #[test]
    fn from_bytes_rejects_wrong_size() {
        let p = profile();
        let s = ModelState::init(&p, 0);
        let mut bytes = s.to_bytes();
        bytes.pop();
        assert!(ModelState::from_bytes(&p, &bytes).is_err());
    }

    #[test]
    fn validate_catches_shape_drift() {
        let p = profile();
        let mut s = ModelState::init(&p, 0);
        s.params[0].pop();
        assert!(s.validate(&p).is_err());
        let mut s = ModelState::init(&p, 0);
        s.step = f32::NAN;
        assert!(s.validate(&p).is_err());
    }
}
