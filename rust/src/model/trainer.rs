//! The training step driver: marshals [`ModelState`] + an
//! [`ImageBatch`] through the AOT train-step executable.
//!
//! Artifact ABI (see `model_meta.json` / `aot.py`):
//!   inputs  = [params*, m*, v*, step, images, labels]
//!   outputs = (params*, m*, v*, step, loss)

use anyhow::{anyhow, Result};

use crate::pipeline::ImageBatch;
use crate::runtime::executable::{lit, ExecSpec};
use crate::runtime::meta::ProfileMeta;
use crate::runtime::Runtime;

use super::params::ModelState;

/// Owns the model state and the compiled step function.
pub struct Trainer {
    profile: ProfileMeta,
    batch_size: usize,
    exe: ExecSpec,
    state: ModelState,
    losses: Vec<f32>,
}

impl Trainer {
    /// Build a trainer for `profile` at a fixed batch size (the HLO is
    /// shape-specialized per batch, as XLA requires).
    pub fn new(rt: &Runtime, profile: &str, batch_size: usize, seed: u64)
        -> Result<Trainer>
    {
        let prof = rt.meta().profile(profile)?.clone();
        let exe = rt.train_step(profile, batch_size)?;
        let state = ModelState::init(&prof, seed);
        Ok(Trainer {
            profile: prof,
            batch_size,
            exe,
            state,
            losses: Vec::new(),
        })
    }

    pub fn profile(&self) -> &ProfileMeta {
        &self.profile
    }

    pub fn state(&self) -> &ModelState {
        &self.state
    }

    /// Replace the state (checkpoint restore).
    pub fn restore(&mut self, state: ModelState) -> Result<()> {
        state.validate(&self.profile)?;
        self.state = state;
        Ok(())
    }

    pub fn losses(&self) -> &[f32] {
        &self.losses
    }

    pub fn step_count(&self) -> u64 {
        self.state.step as u64
    }

    /// Execute one training step; returns the batch loss.
    pub fn step(&mut self, batch: &ImageBatch) -> Result<f32> {
        if batch.batch != self.batch_size {
            return Err(anyhow!(
                "batch size {} != trainer's compiled size {}",
                batch.batch, self.batch_size
            ));
        }
        let s = self.profile.input_size;
        if batch.size as usize != s {
            return Err(anyhow!("image size {} != model input {s}",
                               batch.size));
        }
        if batch.num_classes as usize != self.profile.num_classes {
            return Err(anyhow!("class count mismatch"));
        }

        let n = self.profile.params.len();
        let mut args: Vec<xla::Literal> = Vec::with_capacity(3 * n + 3);
        for group in [&self.state.params, &self.state.m, &self.state.v] {
            for (tensor, spec) in group.iter().zip(&self.profile.params) {
                args.push(lit::f32(&spec.shape, tensor)?);
            }
        }
        args.push(lit::scalar_f32(self.state.step));
        args.push(lit::f32(&[self.batch_size, s, s, 3], &batch.images)?);
        args.push(lit::f32(
            &[self.batch_size, self.profile.num_classes],
            &batch.labels,
        )?);

        let mut out = self.exe.get()?.run(&args)?;
        if out.len() != 3 * n + 2 {
            return Err(anyhow!(
                "train step returned {} outputs, expected {}",
                out.len(), 3 * n + 2
            ));
        }

        // Unpack in reverse to consume the Vec cheaply.
        let loss = out
            .pop()
            .unwrap()
            .to_vec::<f32>()
            .map_err(|e| anyhow!("loss: {e}"))?[0];
        let step = out
            .pop()
            .unwrap()
            .to_vec::<f32>()
            .map_err(|e| anyhow!("step: {e}"))?[0];
        let mut groups: Vec<Vec<Vec<f32>>> = Vec::with_capacity(3);
        for g in 0..3 {
            let mut tensors = Vec::with_capacity(n);
            for (i, l) in out.drain(out.len() - n..).enumerate() {
                let t = l
                    .to_vec::<f32>()
                    .map_err(|e| anyhow!("output {g}/{i}: {e}"))?;
                tensors.push(t);
            }
            groups.push(tensors);
        }
        // groups drained back-to-front: [v, m, params]
        self.state.v = groups.remove(0);
        self.state.m = groups.remove(0);
        self.state.params = groups.remove(0);
        self.state.step = step;
        if !loss.is_finite() {
            return Err(anyhow!("non-finite loss at step {step}"));
        }
        self.losses.push(loss);
        Ok(loss)
    }
}
