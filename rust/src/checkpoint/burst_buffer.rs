//! Proof-of-concept burst buffer (§III-C, Figs. 9-10).
//!
//! The paper: *"when the checkpoint saver is called, a checkpoint is
//! created and synchronized to a fast non-volatile memory device.  At
//! the same time a process is spawned in background to copy the just
//! created files to hard disk for storage.  Since the checkpoint was
//! already written to persistent memory, it is possible to continue
//! training without disruption."*  And §V-C: once drained, staged
//! copies can be cleaned up ("by moving these files to HDD for
//! archiving it is possible to cleanup the buffer"), and the HDD copy
//! needs no immediate sync.
//!
//! Implementation: a [`Saver`] targeting the fast device + one drainer
//! thread consuming a queue of drain jobs (copy triple to the slow
//! device via the engine's chunked pipelined copy, then optionally
//! delete the staged files).  Drains complete strictly oldest-first,
//! and the saver's retention cleanup is guarded so it can never delete
//! a staged checkpoint that is still queued for (or in) drain.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use anyhow::Result;

use crate::model::ModelState;
use crate::runtime::meta::ProfileMeta;
use crate::storage::StorageSim;

use super::saver::{CheckpointHandle, Saver};

struct DrainQueue {
    jobs: Mutex<VecDeque<CheckpointHandle>>,
    available: Condvar,
    idle: Condvar,
    shutdown: Mutex<bool>,
}

impl DrainQueue {
    /// Is `handle` still queued for (or currently in) drain?  Jobs are
    /// popped only after their copy finishes, so a `true` here means
    /// the staged files must not be deleted yet.
    fn contains(&self, handle: &CheckpointHandle) -> bool {
        self.jobs.lock().unwrap().iter().any(|j| j == handle)
    }
}

/// Burst-buffer checkpointer: synchronous save to `fast`, asynchronous
/// drain to `slow`.
pub struct BurstBuffer {
    saver: Saver,
    slow_device: String,
    queue: Arc<DrainQueue>,
    drainer: Option<JoinHandle<()>>,
    drained: Arc<AtomicU64>,
    drain_errors: Arc<AtomicU64>,
    cleanup_staged: Arc<AtomicBool>,
    /// Steps in the order their drains completed (oldest-first proof).
    drained_steps: Arc<Mutex<Vec<u64>>>,
}

impl BurstBuffer {
    pub fn new(
        sim: Arc<StorageSim>,
        profile: ProfileMeta,
        fast_device: &str,
        slow_device: &str,
        prefix: &str,
        max_to_keep: usize,
    ) -> BurstBuffer {
        let mut saver = Saver::new(
            Arc::clone(&sim),
            profile,
            fast_device,
            prefix,
            max_to_keep,
        );
        let queue = Arc::new(DrainQueue {
            jobs: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            idle: Condvar::new(),
            shutdown: Mutex::new(false),
        });
        // Retention cleanup must never race the drainer: staged files
        // still queued for drain are vetoed until their copy lands.
        {
            let q = Arc::clone(&queue);
            saver.set_retention_guard(Arc::new(move |h| !q.contains(h)));
        }
        let drained = Arc::new(AtomicU64::new(0));
        let drain_errors = Arc::new(AtomicU64::new(0));
        let cleanup_staged = Arc::new(AtomicBool::new(false));
        let drained_steps = Arc::new(Mutex::new(Vec::new()));

        let drainer = {
            let q = Arc::clone(&queue);
            let sim = Arc::clone(&sim);
            let slow = slow_device.to_string();
            let drained = Arc::clone(&drained);
            let errors = Arc::clone(&drain_errors);
            let cleanup = Arc::clone(&cleanup_staged);
            let steps = Arc::clone(&drained_steps);
            std::thread::Builder::new()
                .name("dlio-bb-drain".into())
                .spawn(move || drain_loop(q, sim, slow, drained, errors,
                                          cleanup, steps))
                .expect("spawn burst-buffer drainer")
        };

        BurstBuffer {
            saver,
            slow_device: slow_device.to_string(),
            queue,
            drainer: Some(drainer),
            drained,
            drain_errors,
            cleanup_staged,
            drained_steps,
        }
    }

    /// Save to the fast device (synchronous, synced) and enqueue the
    /// asynchronous drain to the slow device.  Returns as soon as the
    /// fast copy is durable — this is the time training is paused.
    pub fn save(&mut self, state: &ModelState, step: u64)
        -> Result<CheckpointHandle>
    {
        let handle = self.saver.save(state, step)?;
        {
            let mut jobs = self.queue.jobs.lock().unwrap();
            jobs.push_back(handle.clone());
        }
        self.queue.available.notify_one();
        Ok(handle)
    }

    /// Delete staged fast-device files once drained — the paper's
    /// "cleanup the buffer for other data" (§V-C).  Off by default so
    /// restores can come from the fast copy.
    pub fn set_cleanup_staged(&self, on: bool) {
        self.cleanup_staged.store(on, Ordering::SeqCst);
    }

    /// Number of checkpoints fully drained to the slow device.
    pub fn drained_count(&self) -> u64 {
        self.drained.load(Ordering::SeqCst)
    }

    /// Steps in drain-completion order (the queue is FIFO, so this is
    /// save order — oldest first).
    pub fn drained_steps(&self) -> Vec<u64> {
        self.drained_steps.lock().unwrap().clone()
    }

    pub fn drain_error_count(&self) -> u64 {
        self.drain_errors.load(Ordering::SeqCst)
    }

    /// Block until every enqueued drain has completed (end-of-run
    /// barrier; the paper notes HDD flushing "continues after the
    /// application ends" — experiments call this to account for it).
    pub fn wait_drained(&self) {
        let mut jobs = self.queue.jobs.lock().unwrap();
        while !jobs.is_empty() {
            jobs = self.queue.idle.wait(jobs).unwrap();
        }
    }

    /// Access to the inner saver (retention list etc.).
    pub fn saver(&self) -> &Saver {
        &self.saver
    }

    pub fn saver_mut(&mut self) -> &mut Saver {
        &mut self.saver
    }

    pub fn slow_device(&self) -> &str {
        &self.slow_device
    }
}

fn drain_loop(
    q: Arc<DrainQueue>,
    sim: Arc<StorageSim>,
    slow: String,
    drained: Arc<AtomicU64>,
    errors: Arc<AtomicU64>,
    cleanup: Arc<AtomicBool>,
    drained_steps: Arc<Mutex<Vec<u64>>>,
) {
    loop {
        let job = {
            let mut jobs = q.jobs.lock().unwrap();
            loop {
                if let Some(j) = jobs.front().cloned() {
                    break j;
                }
                if *q.shutdown.lock().unwrap() {
                    return;
                }
                jobs = q.available.wait(jobs).unwrap();
            }
        };
        // Copy the triple to the slow device — engine-level chunked
        // copies, so the fast-device read overlaps the slow-device
        // write and drain memory stays bounded by the stream window.
        // No syncfs: "it is not necessary to enforce immediate
        // synchronization ... when moved to HDD" (§V-C).
        let mut ok = true;
        for f in job.files() {
            let dst = crate::storage::SimPath::new(slow.clone(), f.rel.clone());
            // Origin-tagged: trace events attribute drain copies to
            // the burst buffer.
            if let Err(e) = crate::storage::with_origin("bb-drain", || {
                sim.copy_class(&f, &dst, crate::storage::IoClass::Drain)
            }) {
                eprintln!("[burst-buffer] drain {f}: {e:#}");
                errors.fetch_add(1, Ordering::SeqCst);
                ok = false;
                break;
            }
        }
        if ok {
            drained.fetch_add(1, Ordering::SeqCst);
            drained_steps.lock().unwrap().push(job.step);
            if cleanup.load(Ordering::SeqCst) {
                for f in job.files() {
                    if sim.exists(&f) {
                        let _ = sim.remove(&f);
                    }
                }
            }
        }
        // Pop the job (lifting the retention-guard veto) and wake any
        // wait_drained() callers.
        let mut jobs = q.jobs.lock().unwrap();
        jobs.pop_front();
        let empty = jobs.is_empty();
        drop(jobs);
        if empty {
            q.idle.notify_all();
        }
    }
}

impl Drop for BurstBuffer {
    fn drop(&mut self) {
        self.wait_drained();
        // Every veto has lifted: apply any retention deletes that were
        // deferred while their checkpoints drained.
        let _ = self.saver.sweep_retention();
        *self.queue.shutdown.lock().unwrap() = true;
        self.queue.available.notify_all();
        if let Some(d) = self.drainer.take() {
            let _ = d.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::meta::{ParamSpec, ProfileMeta};
    use crate::storage::DeviceModel;

    fn model(name: &str, write_lat: f64) -> DeviceModel {
        DeviceModel {
            name: name.into(),
            read_bw: 1e9,
            write_bw: 1e9,
            read_lat: 0.0,
            write_lat,
            channels: 4,
            elevator: vec![(1, 1.0)],
            time_scale: 1.0,
        }
    }

    fn profile() -> ProfileMeta {
        ProfileMeta {
            name: "t".into(),
            input_size: 8,
            num_classes: 4,
            num_params: 4 * 3 + 3,
            params: vec![
                ParamSpec { name: "fc1/kernel".into(), shape: vec![4, 3] },
                ParamSpec { name: "fc1/bias".into(), shape: vec![3] },
            ],
        }
    }

    fn sim(tag: &str, slow_write_lat: f64) -> Arc<StorageSim> {
        let dir = std::env::temp_dir()
            .join(format!("dlio-bb-test-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        Arc::new(
            StorageSim::cold(
                dir,
                vec![model("fast", 0.0), model("slow", slow_write_lat)],
            )
            .unwrap(),
        )
    }

    #[test]
    fn back_to_back_saves_drain_oldest_first_without_retention_races() {
        // Slow drain target (10 ms write latency per file => >=30 ms
        // per triple) + rapid saves with a small retention quota: the
        // old implementation's cleanup deleted staged files before the
        // drainer copied them.  The guard must make every drain land,
        // oldest first, with zero errors.
        let sim = sim("order", 0.010);
        let profile = profile();
        let state = ModelState::init(&profile, 7);
        let steps: Vec<u64> = (1..=6).map(|i| i * 10).collect();
        {
            let mut bb = BurstBuffer::new(
                Arc::clone(&sim),
                profile.clone(),
                "fast",
                "slow",
                "ck/m",
                2, // far fewer than the drain backlog
            );
            bb.saver_mut().sync_on_save = false;
            for &s in &steps {
                bb.save(&state, s).unwrap();
            }
            bb.wait_drained();
            assert_eq!(bb.drain_error_count(), 0, "cleanup raced the drainer");
            assert_eq!(bb.drained_count(), steps.len() as u64);
            assert_eq!(bb.drained_steps(), steps, "drains not oldest-first");
        }
        // Every checkpoint reached the slow device intact.
        for &s in &steps {
            let h = CheckpointHandle {
                device: "slow".into(),
                prefix: "ck/m".into(),
                step: s,
            };
            let back = Saver::restore(&sim, &profile, &h).unwrap();
            assert_eq!(back.params, state.params);
        }
        // After drop (drains settled + deferred sweep), retention
        // holds on the fast device: only the newest 2 staged remain.
        for &s in &steps[..4] {
            assert!(
                !sim.exists(&crate::storage::SimPath::new(
                    "fast",
                    format!("ck/m-{s}.data"),
                )),
                "step {s} staged files should be cleaned up"
            );
        }
        for &s in &steps[4..] {
            assert!(sim.exists(&crate::storage::SimPath::new(
                "fast",
                format!("ck/m-{s}.data"),
            )));
        }
    }

    #[test]
    fn cleanup_staged_removes_fast_copies_after_drain() {
        let sim = sim("staged", 0.0);
        let profile = profile();
        let state = ModelState::init(&profile, 1);
        let mut bb = BurstBuffer::new(
            Arc::clone(&sim),
            profile.clone(),
            "fast",
            "slow",
            "ck/m",
            5,
        );
        bb.saver_mut().sync_on_save = false;
        bb.set_cleanup_staged(true);
        let h = bb.save(&state, 10).unwrap();
        bb.wait_drained();
        assert_eq!(bb.drain_error_count(), 0);
        // Staged copy gone, slow copy restorable.
        assert!(!sim.exists(&h.file("data")));
        let slow = CheckpointHandle {
            device: "slow".into(),
            prefix: "ck/m".into(),
            step: 10,
        };
        assert!(Saver::restore(&sim, &profile, &slow).is_ok());
    }
}
