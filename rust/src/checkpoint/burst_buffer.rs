//! Proof-of-concept burst buffer (§III-C, Figs. 9-10).
//!
//! The paper: *"when the checkpoint saver is called, a checkpoint is
//! created and synchronized to a fast non-volatile memory device.  At
//! the same time a process is spawned in background to copy the just
//! created files to hard disk for storage.  Since the checkpoint was
//! already written to persistent memory, it is possible to continue
//! training without disruption."*  And §V-C: once drained, staged
//! copies can be cleaned up ("by moving these files to HDD for
//! archiving it is possible to cleanup the buffer"), and the HDD copy
//! needs no immediate sync.
//!
//! Implementation: a [`Saver`] targeting the fast device + one drainer
//! thread consuming a queue of drain jobs (copy triple to the slow
//! device, then optionally delete the staged files).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use anyhow::Result;

use crate::model::ModelState;
use crate::runtime::meta::ProfileMeta;
use crate::storage::StorageSim;

use super::saver::{CheckpointHandle, Saver};

struct DrainQueue {
    jobs: Mutex<VecDeque<CheckpointHandle>>,
    available: Condvar,
    idle: Condvar,
    shutdown: Mutex<bool>,
}

/// Burst-buffer checkpointer: synchronous save to `fast`, asynchronous
/// drain to `slow`.
pub struct BurstBuffer {
    saver: Saver,
    slow_device: String,
    queue: Arc<DrainQueue>,
    drainer: Option<JoinHandle<()>>,
    drained: Arc<AtomicU64>,
    drain_errors: Arc<AtomicU64>,
    cleanup_staged: Arc<AtomicBool>,
}

impl BurstBuffer {
    pub fn new(
        sim: Arc<StorageSim>,
        profile: ProfileMeta,
        fast_device: &str,
        slow_device: &str,
        prefix: &str,
        max_to_keep: usize,
    ) -> BurstBuffer {
        let saver = Saver::new(
            Arc::clone(&sim),
            profile,
            fast_device,
            prefix,
            max_to_keep,
        );
        let queue = Arc::new(DrainQueue {
            jobs: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            idle: Condvar::new(),
            shutdown: Mutex::new(false),
        });
        let drained = Arc::new(AtomicU64::new(0));
        let drain_errors = Arc::new(AtomicU64::new(0));
        let cleanup_staged = Arc::new(AtomicBool::new(false));

        let drainer = {
            let q = Arc::clone(&queue);
            let sim = Arc::clone(&sim);
            let slow = slow_device.to_string();
            let drained = Arc::clone(&drained);
            let errors = Arc::clone(&drain_errors);
            let cleanup = Arc::clone(&cleanup_staged);
            std::thread::Builder::new()
                .name("dlio-bb-drain".into())
                .spawn(move || drain_loop(q, sim, slow, drained, errors,
                                          cleanup))
                .expect("spawn burst-buffer drainer")
        };

        BurstBuffer {
            saver,
            slow_device: slow_device.to_string(),
            queue,
            drainer: Some(drainer),
            drained,
            drain_errors,
            cleanup_staged,
        }
    }

    /// Save to the fast device (synchronous, synced) and enqueue the
    /// asynchronous drain to the slow device.  Returns as soon as the
    /// fast copy is durable — this is the time training is paused.
    pub fn save(&mut self, state: &ModelState, step: u64)
        -> Result<CheckpointHandle>
    {
        let handle = self.saver.save(state, step)?;
        {
            let mut jobs = self.queue.jobs.lock().unwrap();
            jobs.push_back(handle.clone());
        }
        self.queue.available.notify_one();
        Ok(handle)
    }

    /// Delete staged fast-device files once drained — the paper's
    /// "cleanup the buffer for other data" (§V-C).  Off by default so
    /// restores can come from the fast copy.
    pub fn set_cleanup_staged(&self, on: bool) {
        self.cleanup_staged.store(on, Ordering::SeqCst);
    }

    /// Number of checkpoints fully drained to the slow device.
    pub fn drained_count(&self) -> u64 {
        self.drained.load(Ordering::SeqCst)
    }

    pub fn drain_error_count(&self) -> u64 {
        self.drain_errors.load(Ordering::SeqCst)
    }

    /// Block until every enqueued drain has completed (end-of-run
    /// barrier; the paper notes HDD flushing "continues after the
    /// application ends" — experiments call this to account for it).
    pub fn wait_drained(&self) {
        let mut jobs = self.queue.jobs.lock().unwrap();
        while !jobs.is_empty() {
            jobs = self.queue.idle.wait(jobs).unwrap();
        }
    }

    /// Access to the inner saver (retention list etc.).
    pub fn saver(&self) -> &Saver {
        &self.saver
    }

    pub fn saver_mut(&mut self) -> &mut Saver {
        &mut self.saver
    }

    pub fn slow_device(&self) -> &str {
        &self.slow_device
    }
}

fn drain_loop(
    q: Arc<DrainQueue>,
    sim: Arc<StorageSim>,
    slow: String,
    drained: Arc<AtomicU64>,
    errors: Arc<AtomicU64>,
    cleanup: Arc<AtomicBool>,
) {
    loop {
        let job = {
            let mut jobs = q.jobs.lock().unwrap();
            loop {
                if let Some(j) = jobs.front().cloned() {
                    break j;
                }
                if *q.shutdown.lock().unwrap() {
                    return;
                }
                jobs = q.available.wait(jobs).unwrap();
            }
        };
        // Copy the triple to the slow device.  No syncfs: "it is not
        // necessary to enforce immediate synchronization ... when moved
        // to HDD" (§V-C).
        let mut ok = true;
        for f in job.files() {
            let dst = crate::storage::SimPath::new(slow.clone(), f.rel.clone());
            if let Err(e) = sim.copy(&f, &dst) {
                eprintln!("[burst-buffer] drain {f}: {e:#}");
                errors.fetch_add(1, Ordering::SeqCst);
                ok = false;
                break;
            }
        }
        if ok {
            drained.fetch_add(1, Ordering::SeqCst);
            if cleanup.load(Ordering::SeqCst) {
                for f in job.files() {
                    if sim.exists(&f) {
                        let _ = sim.remove(&f);
                    }
                }
            }
        }
        // Pop the job and wake any wait_drained() callers.
        let mut jobs = q.jobs.lock().unwrap();
        jobs.pop_front();
        let empty = jobs.is_empty();
        drop(jobs);
        if empty {
            q.idle.notify_all();
        }
    }
}

impl Drop for BurstBuffer {
    fn drop(&mut self) {
        self.wait_drained();
        *self.queue.shutdown.lock().unwrap() = true;
        self.queue.available.notify_all();
        if let Some(d) = self.drainer.take() {
            let _ = d.join();
        }
    }
}
