//! Proof-of-concept burst buffer (§III-C, Figs. 9-10).
//!
//! The paper: *"when the checkpoint saver is called, a checkpoint is
//! created and synchronized to a fast non-volatile memory device.  At
//! the same time a process is spawned in background to copy the just
//! created files to hard disk for storage.  Since the checkpoint was
//! already written to persistent memory, it is possible to continue
//! training without disruption."*  And §V-C: once drained, staged
//! copies can be cleaned up ("by moving these files to HDD for
//! archiving it is possible to cleanup the buffer"), and the HDD copy
//! needs no immediate sync.
//!
//! Since the N-tier refactor (DESIGN.md §12) this is a *thin wrapper*
//! over a 2-tier [`StorageHierarchy`]: the saver routes through the
//! hierarchy (tier 0 = `fast`), each saved triple is enqueued as one
//! labelled migration group to tier 1 (`slow`), and the hierarchy's
//! single FIFO migrator preserves the original guarantees by
//! construction — drains complete strictly oldest-first, the
//! retention guard vetoes any staged checkpoint whose drain group is
//! still pending, and `--drain-cap-mbs` still applies because every
//! drain is an engine `Drain`-class copy.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::Result;

use crate::model::ModelState;
use crate::runtime::meta::ProfileMeta;
use crate::storage::{policy, HierarchySpec, StorageHierarchy, StorageSim};

use super::saver::{CheckpointHandle, Saver};

/// Burst-buffer checkpointer: synchronous save to `fast` (tier 0),
/// asynchronous drain to `slow` (tier 1).
pub struct BurstBuffer {
    saver: Saver,
    hier: Arc<StorageHierarchy>,
    slow_device: String,
    cleanup_staged: Arc<AtomicBool>,
}

impl BurstBuffer {
    /// Errors when `fast_device`/`slow_device` don't exist in the sim
    /// (the hierarchy validates its tiers at construction).
    pub fn new(
        sim: Arc<StorageSim>,
        profile: ProfileMeta,
        fast_device: &str,
        slow_device: &str,
        prefix: &str,
        max_to_keep: usize,
    ) -> Result<BurstBuffer> {
        let hier = Arc::new(StorageHierarchy::new(
            Arc::clone(&sim),
            HierarchySpec::two_tier_bb(fast_device, slow_device),
            Box::new(policy::Noop),
        )?);
        let mut saver = Saver::new(
            Arc::clone(&sim),
            profile,
            fast_device,
            prefix,
            max_to_keep,
        );
        saver.set_route(Arc::clone(&hier));
        // Retention cleanup must never race the drainer: staged files
        // whose drain group is still queued (or in flight) are vetoed
        // until their copies land — groups pop only after completion.
        {
            let h = Arc::clone(&hier);
            saver.set_retention_guard(Arc::new(move |handle| {
                !h.group_pending(handle.step)
            }));
        }
        Ok(BurstBuffer {
            saver,
            hier,
            slow_device: slow_device.to_string(),
            cleanup_staged: Arc::new(AtomicBool::new(false)),
        })
    }

    /// Save to the fast tier (synchronous, synced) and enqueue the
    /// asynchronous drain of the triple to the slow tier.  Returns as
    /// soon as the fast copy is durable — this is the time training
    /// is paused.
    pub fn save(&mut self, state: &ModelState, step: u64)
        -> Result<CheckpointHandle>
    {
        let handle = self.saver.save(state, step)?;
        let keys: Vec<String> =
            handle.files().iter().map(|f| f.rel.clone()).collect();
        self.hier.enqueue_group(
            step,
            keys,
            0,
            1,
            "bb-drain",
            Some(Arc::clone(&self.cleanup_staged)),
        )?;
        Ok(handle)
    }

    /// Delete staged fast-tier files once drained — the paper's
    /// "cleanup the buffer for other data" (§V-C).  Off by default so
    /// restores can come from the fast copy.
    pub fn set_cleanup_staged(&self, on: bool) {
        self.cleanup_staged.store(on, Ordering::SeqCst);
    }

    /// Number of checkpoints fully drained to the slow tier.
    pub fn drained_count(&self) -> u64 {
        self.hier.completed_count()
    }

    /// Steps in drain-completion order (the migrator is FIFO, so this
    /// is save order — oldest first).
    pub fn drained_steps(&self) -> Vec<u64> {
        self.hier.completed_labels()
    }

    pub fn drain_error_count(&self) -> u64 {
        self.hier.migration_errors()
    }

    /// Block until every enqueued drain has completed (end-of-run
    /// barrier; the paper notes HDD flushing "continues after the
    /// application ends" — experiments call this to account for it).
    pub fn wait_drained(&self) {
        self.hier.wait_idle();
    }

    /// Access to the inner saver (retention list etc.).
    pub fn saver(&self) -> &Saver {
        &self.saver
    }

    pub fn saver_mut(&mut self) -> &mut Saver {
        &mut self.saver
    }

    pub fn slow_device(&self) -> &str {
        &self.slow_device
    }

    /// The 2-tier hierarchy backing this buffer (per-tier stats,
    /// tier-sweep cells).
    pub fn hierarchy(&self) -> &Arc<StorageHierarchy> {
        &self.hier
    }
}

impl Drop for BurstBuffer {
    fn drop(&mut self) {
        self.wait_drained();
        // Every veto has lifted: apply any retention deletes that were
        // deferred while their checkpoints drained.  (The hierarchy's
        // migrator joins when the last Arc drops with this struct.)
        let _ = self.saver.sweep_retention();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::meta::{ParamSpec, ProfileMeta};
    use crate::storage::DeviceModel;

    fn model(name: &str, write_lat: f64) -> DeviceModel {
        DeviceModel {
            name: name.into(),
            read_bw: 1e9,
            write_bw: 1e9,
            read_lat: 0.0,
            write_lat,
            channels: 4,
            elevator: vec![(1, 1.0)],
            time_scale: 1.0,
            lat_tables: None,
        }
    }

    fn profile() -> ProfileMeta {
        ProfileMeta {
            name: "t".into(),
            input_size: 8,
            num_classes: 4,
            num_params: 4 * 3 + 3,
            params: vec![
                ParamSpec { name: "fc1/kernel".into(), shape: vec![4, 3] },
                ParamSpec { name: "fc1/bias".into(), shape: vec![3] },
            ],
        }
    }

    fn sim(tag: &str, slow_write_lat: f64) -> Arc<StorageSim> {
        let dir = std::env::temp_dir()
            .join(format!("dlio-bb-test-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        Arc::new(
            StorageSim::cold(
                dir,
                vec![model("fast", 0.0), model("slow", slow_write_lat)],
            )
            .unwrap(),
        )
    }

    #[test]
    fn back_to_back_saves_drain_oldest_first_without_retention_races() {
        // Slow drain target (10 ms write latency per file => >=30 ms
        // per triple) + rapid saves with a small retention quota: the
        // old implementation's cleanup deleted staged files before the
        // drainer copied them.  The guard must make every drain land,
        // oldest first, with zero errors.
        let sim = sim("order", 0.010);
        let profile = profile();
        let state = ModelState::init(&profile, 7);
        let steps: Vec<u64> = (1..=6).map(|i| i * 10).collect();
        {
            let mut bb = BurstBuffer::new(
                Arc::clone(&sim),
                profile.clone(),
                "fast",
                "slow",
                "ck/m",
                2, // far fewer than the drain backlog
            )
            .unwrap();
            bb.saver_mut().sync_on_save = false;
            for &s in &steps {
                bb.save(&state, s).unwrap();
            }
            bb.wait_drained();
            assert_eq!(bb.drain_error_count(), 0, "cleanup raced the drainer");
            assert_eq!(bb.drained_count(), steps.len() as u64);
            assert_eq!(bb.drained_steps(), steps, "drains not oldest-first");
        }
        // Every checkpoint reached the slow device intact.
        for &s in &steps {
            let h = CheckpointHandle {
                device: "slow".into(),
                prefix: "ck/m".into(),
                step: s,
            };
            let back = Saver::restore(&sim, &profile, &h).unwrap();
            assert_eq!(back.params, state.params);
        }
        // After drop (drains settled + deferred sweep), retention
        // holds on the fast device: only the newest 2 staged remain.
        for &s in &steps[..4] {
            assert!(
                !sim.exists(&crate::storage::SimPath::new(
                    "fast",
                    format!("ck/m-{s}.data"),
                )),
                "step {s} staged files should be cleaned up"
            );
        }
        for &s in &steps[4..] {
            assert!(sim.exists(&crate::storage::SimPath::new(
                "fast",
                format!("ck/m-{s}.data"),
            )));
        }
    }

    #[test]
    fn cleanup_staged_removes_fast_copies_after_drain() {
        let sim = sim("staged", 0.0);
        let profile = profile();
        let state = ModelState::init(&profile, 1);
        let mut bb = BurstBuffer::new(
            Arc::clone(&sim),
            profile.clone(),
            "fast",
            "slow",
            "ck/m",
            5,
        )
        .unwrap();
        bb.saver_mut().sync_on_save = false;
        bb.set_cleanup_staged(true);
        let h = bb.save(&state, 10).unwrap();
        bb.wait_drained();
        assert_eq!(bb.drain_error_count(), 0);
        // Staged copy gone, slow copy restorable.
        assert!(!sim.exists(&h.file("data")));
        let slow = CheckpointHandle {
            device: "slow".into(),
            prefix: "ck/m".into(),
            step: 10,
        };
        assert!(Saver::restore(&sim, &profile, &slow).is_ok());
    }

    #[test]
    fn mid_drain_fault_pauses_drains_and_loses_no_checkpoints() {
        // DESIGN.md §15 / bench §14 gate at unit scale: the slow tier
        // goes offline for the first 80 ms — saves keep landing on the
        // (healthy) fast tier, the migrator pauses and requeues
        // instead of erroring, and once the fault clears every
        // checkpoint drains oldest-first with nothing lost.
        use crate::storage::FaultPlan;
        let sim = sim("fault", 0.004);
        sim.apply_fault_plan(
            &FaultPlan::parse("offline:slow:0:0.08").unwrap(),
        )
        .unwrap();
        let profile = profile();
        let state = ModelState::init(&profile, 5);
        let steps: Vec<u64> = (1..=4).map(|i| i * 10).collect();
        {
            let mut bb = BurstBuffer::new(
                Arc::clone(&sim),
                profile.clone(),
                "fast",
                "slow",
                "ck/m",
                2, // retention quota below the paused backlog
            )
            .unwrap();
            bb.saver_mut().sync_on_save = false;
            for &s in &steps {
                bb.save(&state, s).unwrap();
            }
            bb.wait_drained();
            assert_eq!(
                bb.drain_error_count(),
                0,
                "paused drains must not be counted as errors"
            );
            assert!(
                bb.hierarchy().migration_pauses() >= 1,
                "fault window never paused the migrator"
            );
            assert_eq!(
                bb.drained_steps(),
                steps,
                "drains must stay oldest-first across the fault"
            );
        }
        // Zero checkpoints lost: every triple restores from the slow
        // tier after the fault cleared (the retention guard held the
        // staged copies while their drain groups sat paused).
        for &s in &steps {
            let h = CheckpointHandle {
                device: "slow".into(),
                prefix: "ck/m".into(),
                step: s,
            };
            let back = Saver::restore(&sim, &profile, &h).unwrap();
            assert_eq!(back.params, state.params, "step {s} corrupted");
        }
        sim.clear_faults();
    }

    #[test]
    fn two_tier_hierarchy_reproduces_bb_drain_counts_and_residency() {
        // The refactor's acceptance test: the wrapper's hierarchy
        // reports exactly the drain counts/order the BurstBuffer API
        // reports, and per-tier stats see the staged triple land on
        // tier 0 and migrate into tier 1.
        let sim = sim("parity", 0.002);
        let profile = profile();
        let state = ModelState::init(&profile, 3);
        let mut bb = BurstBuffer::new(
            Arc::clone(&sim),
            profile.clone(),
            "fast",
            "slow",
            "ck/m",
            5,
        )
        .unwrap();
        bb.saver_mut().sync_on_save = false;
        let steps: Vec<u64> = vec![5, 10, 15];
        for &s in &steps {
            bb.save(&state, s).unwrap();
        }
        bb.wait_drained();
        assert_eq!(bb.drained_steps(), steps);
        let hier = bb.hierarchy();
        assert_eq!(hier.completed_labels(), steps, "hierarchy = BB ledger");
        // 3 triples x 3 files migrated into tier 1, none evicted from
        // tier 0 (cleanup off).
        let stats = hier.stats();
        assert_eq!(stats[1].migrations_in, 9);
        assert_eq!(stats[0].evictions, 0);
        // Residency: every file on both tiers.
        for &s in &steps {
            for suffix in ["meta", "index", "data"] {
                let key = format!("ck/m-{s}.{suffix}");
                assert_eq!(hier.tiers_of(&key), vec![0, 1], "{key}");
            }
        }
    }
}
