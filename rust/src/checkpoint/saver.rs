//! `tf.train.Saver` work-alike (§II-B).
//!
//! Saving a checkpoint emits the same file triple TensorFlow does:
//!
//! * `<prefix>-<step>.meta`  — graph structure (here: profile name +
//!   ordered tensor names/shapes, as JSON),
//! * `<prefix>-<step>.index` — tensor -> (offset, length) table into
//!   the data file,
//! * `<prefix>-<step>.data`  — the raw variable contents
//!   (params + Adam moments + step, little-endian f32).
//!
//! Semantics reproduced from the paper: saving is synchronous (training
//! pauses — "TensorFlow currently does not support overlap of
//! checkpointing and computation", §VII), a `syncfs()` follows every
//! save (§III-C), and only the most recent `max_to_keep` checkpoints
//! are retained (default five, §II-B).

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use crate::model::ModelState;
use crate::runtime::meta::ProfileMeta;
use crate::storage::{SimPath, StorageSim};
use crate::util::json::{obj, to_string, Json};

/// Identifies one saved checkpoint.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CheckpointHandle {
    pub device: String,
    pub prefix: String,
    pub step: u64,
}

impl CheckpointHandle {
    pub fn file(&self, suffix: &str) -> SimPath {
        SimPath::new(
            self.device.clone(),
            format!("{}-{}.{}", self.prefix, self.step, suffix),
        )
    }

    pub fn files(&self) -> [SimPath; 3] {
        [self.file("meta"), self.file("index"), self.file("data")]
    }
}

/// The checkpoint saver.
pub struct Saver {
    sim: Arc<StorageSim>,
    profile: ProfileMeta,
    device: String,
    prefix: String,
    max_to_keep: usize,
    saved: Vec<CheckpointHandle>,
    /// Skip the post-save syncfs (used by tests; experiments keep it).
    pub sync_on_save: bool,
}

impl Saver {
    /// `prefix` is the path prefix *within* `device`, e.g.
    /// `"ckpt/model"` -> `device://ckpt/model-120.data`.
    pub fn new(
        sim: Arc<StorageSim>,
        profile: ProfileMeta,
        device: &str,
        prefix: &str,
        max_to_keep: usize,
    ) -> Saver {
        Saver {
            sim,
            profile,
            device: device.to_string(),
            prefix: prefix.to_string(),
            max_to_keep: max_to_keep.max(1),
            saved: Vec::new(),
            sync_on_save: true,
        }
    }

    fn meta_json(&self) -> String {
        let params: Vec<Json> = self
            .profile
            .params
            .iter()
            .map(|p| {
                obj(vec![
                    ("name", Json::Str(p.name.clone())),
                    (
                        "shape",
                        Json::Arr(
                            p.shape
                                .iter()
                                .map(|&d| Json::Num(d as f64))
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect();
        to_string(&obj(vec![
            ("profile", Json::Str(self.profile.name.clone())),
            ("params", Json::Arr(params)),
        ]))
    }

    fn index_json(&self) -> String {
        // Offsets into the .data payload: params, then m, then v.
        let mut entries = BTreeMap::new();
        let mut offset = 0u64;
        for group in ["", "m/", "v/"] {
            for p in &self.profile.params {
                let len = p.num_elements() as u64 * 4;
                entries.insert(
                    format!("{group}{}", p.name),
                    obj(vec![
                        ("offset", Json::Num(offset as f64)),
                        ("len", Json::Num(len as f64)),
                    ]),
                );
                offset += len;
            }
        }
        entries.insert(
            "global_step".into(),
            obj(vec![
                ("offset", Json::Num(offset as f64)),
                ("len", Json::Num(4.0)),
            ]),
        );
        to_string(&Json::Obj(entries))
    }

    /// Save a checkpoint of `state` at training step `step`.
    /// Synchronous: returns once all three files are written (and
    /// synced, unless `sync_on_save` is off).
    pub fn save(&mut self, state: &ModelState, step: u64)
        -> Result<CheckpointHandle>
    {
        state.validate(&self.profile)?;
        let handle = CheckpointHandle {
            device: self.device.clone(),
            prefix: self.prefix.clone(),
            step,
        };
        self.sim
            .write(&handle.file("meta"), self.meta_json().as_bytes())?;
        self.sim
            .write(&handle.file("index"), self.index_json().as_bytes())?;
        self.sim.write(&handle.file("data"), &state.to_bytes())?;
        if self.sync_on_save {
            // §III-C: "we perform disk synchronization ... immediately
            // after Saver returns".
            self.sim.syncfs(&self.device)?;
        }
        self.saved.push(handle.clone());
        self.cleanup()?;
        Ok(handle)
    }

    /// Retention: keep only the newest `max_to_keep` checkpoints.
    fn cleanup(&mut self) -> Result<()> {
        while self.saved.len() > self.max_to_keep {
            let victim = self.saved.remove(0);
            for f in victim.files() {
                if self.sim.exists(&f) {
                    self.sim.remove(&f)?;
                }
            }
        }
        Ok(())
    }

    /// Checkpoints currently retained, oldest first.
    pub fn retained(&self) -> &[CheckpointHandle] {
        &self.saved
    }

    /// Restore a state from a handle (graph meta first, then
    /// variables — the order §II-B describes).
    pub fn restore(
        sim: &StorageSim,
        profile: &ProfileMeta,
        handle: &CheckpointHandle,
    ) -> Result<ModelState> {
        let meta_bytes = sim
            .read(&handle.file("meta"))
            .context("reading checkpoint .meta")?;
        let meta = Json::parse(std::str::from_utf8(&meta_bytes)?)
            .context("parsing checkpoint .meta")?;
        let saved_profile = meta
            .get("profile")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!(".meta missing profile"))?;
        if saved_profile != profile.name {
            return Err(anyhow!(
                "checkpoint is for profile {saved_profile:?}, \
                 trainer uses {:?}", profile.name
            ));
        }
        let n_meta = meta
            .get("params")
            .and_then(Json::as_arr)
            .map(|a| a.len())
            .unwrap_or(0);
        if n_meta != profile.params.len() {
            return Err(anyhow!(
                ".meta has {n_meta} tensors, profile has {}",
                profile.params.len()
            ));
        }
        let data = sim
            .read(&handle.file("data"))
            .context("reading checkpoint .data")?;
        let state = ModelState::from_bytes(profile, &data)?;
        state.validate(profile)?;
        Ok(state)
    }

    /// Find the latest checkpoint under `device://dir` with `prefix`.
    pub fn latest(
        sim: &StorageSim,
        device: &str,
        prefix: &str,
    ) -> Result<Option<CheckpointHandle>> {
        let dir = match prefix.rsplit_once('/') {
            Some((d, _)) => d,
            None => "",
        };
        let mut best: Option<CheckpointHandle> = None;
        for p in sim.list(device, dir)? {
            if let Some(rest) = p
                .rel
                .strip_prefix(&format!("{prefix}-"))
                .and_then(|r| r.strip_suffix(".data"))
            {
                if let Ok(step) = rest.parse::<u64>() {
                    if best.as_ref().map_or(true, |b| step > b.step) {
                        best = Some(CheckpointHandle {
                            device: device.to_string(),
                            prefix: prefix.to_string(),
                            step,
                        });
                    }
                }
            }
        }
        Ok(best)
    }
}
