//! `tf.train.Saver` work-alike (§II-B).
//!
//! Saving a checkpoint emits the same file triple TensorFlow does:
//!
//! * `<prefix>-<step>.meta`  — graph structure (here: profile name +
//!   ordered tensor names/shapes, as JSON),
//! * `<prefix>-<step>.index` — tensor -> (offset, length) table into
//!   the data file,
//! * `<prefix>-<step>.data`  — the raw variable contents
//!   (params + Adam moments + step, little-endian f32).
//!
//! Semantics reproduced from the paper: saving is synchronous (training
//! pauses — "TensorFlow currently does not support overlap of
//! checkpointing and computation", §VII), a `syncfs()` follows every
//! save (§III-C), and only the most recent `max_to_keep` checkpoints
//! are retained (default five, §II-B).
//!
//! Internally the triple is no longer three serial blocking writes:
//! all three files are submitted to the [`IoEngine`] at once (meta and
//! index overlap the data write, and the deeper queue buys the HDD
//! elevator gain), and the `.data` payload streams through a bounded
//! chunk window instead of one contiguous buffer.  `save` still
//! returns only when all three files are durable, so the measured
//! "training paused" semantics are unchanged.
//!
//! [`IoEngine`]: crate::storage::IoEngine

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::model::ModelState;
use crate::runtime::meta::ProfileMeta;
use crate::storage::{
    with_origin, with_tier, IoClass, SimPath, StorageHierarchy, StorageSim,
};
use crate::util::json::{obj, to_string, Json};

/// Decides whether a retention victim may be deleted yet (the burst
/// buffer vetoes staged checkpoints still queued for drain, so cleanup
/// can never race the drainer).
pub type RetentionGuard =
    Arc<dyn Fn(&CheckpointHandle) -> bool + Send + Sync>;

/// Identifies one saved checkpoint.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CheckpointHandle {
    pub device: String,
    pub prefix: String,
    pub step: u64,
}

impl CheckpointHandle {
    pub fn file(&self, suffix: &str) -> SimPath {
        SimPath::new(
            self.device.clone(),
            format!("{}-{}.{}", self.prefix, self.step, suffix),
        )
    }

    pub fn files(&self) -> [SimPath; 3] {
        [self.file("meta"), self.file("index"), self.file("data")]
    }
}

/// The checkpoint saver.
pub struct Saver {
    sim: Arc<StorageSim>,
    profile: ProfileMeta,
    device: String,
    prefix: String,
    max_to_keep: usize,
    saved: Vec<CheckpointHandle>,
    retention_guard: Option<RetentionGuard>,
    /// When set, saves route through the storage hierarchy: the
    /// placement policy picks the tier each triple lands on, writes
    /// are tier-tagged (trace events + per-tier stats), residency is
    /// registered (triggering write-through drains), and retention
    /// removes only this tier's copies — drained archive copies
    /// survive.
    route: Option<Arc<StorageHierarchy>>,
    /// Skip the post-save syncfs (used by tests; experiments keep it).
    pub sync_on_save: bool,
}

/// Run `f` under the saver's origin tag, adding the hierarchy tier
/// tag when the saver is routed (one generic helper because the two
/// write paths return different types).
fn tagged<T>(tier: Option<usize>, f: impl FnOnce() -> T) -> T {
    match tier {
        Some(t) => with_origin("saver", || with_tier(t as u32, f)),
        None => with_origin("saver", f),
    }
}

/// The `.data` layout shared by the index writer and the restore-side
/// validator: tensor name -> (offset, len), params then m then v, plus
/// the trailing `global_step`.
fn data_layout(profile: &ProfileMeta) -> BTreeMap<String, (u64, u64)> {
    let mut entries = BTreeMap::new();
    let mut offset = 0u64;
    for group in ["", "m/", "v/"] {
        for p in &profile.params {
            let len = p.num_elements() as u64 * 4;
            entries.insert(format!("{group}{}", p.name), (offset, len));
            offset += len;
        }
    }
    entries.insert("global_step".into(), (offset, 4));
    entries
}

/// Parse a `.index` payload and check every tensor's (offset, len)
/// against the profile's layout and the actual `.data` size.
fn validate_index(
    index_bytes: &[u8],
    profile: &ProfileMeta,
    data_len: u64,
) -> Result<()> {
    let index = Json::parse(std::str::from_utf8(index_bytes)?)
        .context("parsing checkpoint .index")?;
    let entries = index
        .as_obj()
        .ok_or_else(|| anyhow!(".index is not an object"))?;
    let expected = data_layout(profile);
    if entries.len() != expected.len() {
        bail!(
            ".index has {} entries, profile expects {}",
            entries.len(),
            expected.len()
        );
    }
    for (name, (offset, len)) in &expected {
        let e = entries
            .get(name)
            .ok_or_else(|| anyhow!(".index missing tensor {name:?}"))?;
        let got_offset = e
            .get("offset")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!(".index {name:?} missing offset"))?;
        let got_len = e
            .get("len")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!(".index {name:?} missing len"))?;
        if got_offset != *offset as f64 || got_len != *len as f64 {
            bail!(
                ".index corrupt for {name:?}: ({got_offset}, {got_len}) \
                 vs expected ({offset}, {len})"
            );
        }
        if offset + len > data_len {
            bail!(
                ".index {name:?} extends to {} past .data end {data_len}",
                offset + len
            );
        }
    }
    let total = expected
        .values()
        .map(|(o, l)| o + l)
        .max()
        .unwrap_or(0);
    if total != data_len {
        bail!(".index covers {total} bytes, .data has {data_len}");
    }
    Ok(())
}

impl Saver {
    /// `prefix` is the path prefix *within* `device`, e.g.
    /// `"ckpt/model"` -> `device://ckpt/model-120.data`.
    pub fn new(
        sim: Arc<StorageSim>,
        profile: ProfileMeta,
        device: &str,
        prefix: &str,
        max_to_keep: usize,
    ) -> Saver {
        Saver {
            sim,
            profile,
            device: device.to_string(),
            prefix: prefix.to_string(),
            max_to_keep: max_to_keep.max(1),
            saved: Vec::new(),
            retention_guard: None,
            route: None,
            sync_on_save: true,
        }
    }

    /// Route saves through `hier` (see the `route` field docs).  The
    /// saver's default device becomes the hierarchy's current write
    /// placement.
    pub fn set_route(&mut self, hier: Arc<StorageHierarchy>) {
        let (_tier, dev) = hier.write_placement();
        self.device = dev;
        self.route = Some(hier);
    }

    /// Install a retention veto: `cleanup` skips (and retries on the
    /// next save) any victim for which the guard returns `false`.
    pub fn set_retention_guard(&mut self, guard: RetentionGuard) {
        self.retention_guard = Some(guard);
    }

    fn meta_json(&self) -> String {
        let params: Vec<Json> = self
            .profile
            .params
            .iter()
            .map(|p| {
                obj(vec![
                    ("name", Json::Str(p.name.clone())),
                    (
                        "shape",
                        Json::Arr(
                            p.shape
                                .iter()
                                .map(|&d| Json::Num(d as f64))
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect();
        to_string(&obj(vec![
            ("profile", Json::Str(self.profile.name.clone())),
            ("params", Json::Arr(params)),
        ]))
    }

    fn index_json(&self) -> String {
        // Offsets into the .data payload: params, then m, then v.
        let entries: BTreeMap<String, Json> = data_layout(&self.profile)
            .into_iter()
            .map(|(name, (offset, len))| {
                (
                    name,
                    obj(vec![
                        ("offset", Json::Num(offset as f64)),
                        ("len", Json::Num(len as f64)),
                    ]),
                )
            })
            .collect();
        to_string(&Json::Obj(entries))
    }

    /// Save a checkpoint of `state` at training step `step`.
    /// Synchronous: returns once all three files are written (and
    /// synced, unless `sync_on_save` is off).  Internally the three
    /// writes are overlapping engine submissions and the data payload
    /// streams through a bounded chunk window.
    pub fn save(&mut self, state: &ModelState, step: u64)
        -> Result<CheckpointHandle>
    {
        state.validate(&self.profile)?;
        // Routed savers ask the placement policy where this triple
        // lands (and tier-tag the writes); unrouted savers keep their
        // fixed device.
        let (tier, device) = match &self.route {
            Some(hier) => {
                let (t, dev) = hier.write_placement();
                (Some(t), dev)
            }
            None => (None, self.device.clone()),
        };
        let handle = CheckpointHandle {
            device,
            prefix: self.prefix.clone(),
            step,
        };
        // One doorbell for meta+index so the device sees the burst,
        // then the data payload streams behind them in bounded chunks.
        // Submissions are origin-tagged so trace events attribute the
        // triple to the saver (and tier-tagged when routed).
        let meta_path = handle.file("meta");
        let index_path = handle.file("index");
        let small = tagged(tier, || {
            self.sim.write_batch_async_class(
                vec![
                    (&meta_path, self.meta_json().into_bytes()),
                    (&index_path, self.index_json().into_bytes()),
                ],
                IoClass::Checkpoint,
            )
        })?;
        let data_path = handle.file("data");
        let (mut data_writer, data) = tagged(tier, || {
            self.sim.write_stream_class(&data_path, IoClass::Checkpoint)
        })?;
        state.stream_bytes(|bytes| data_writer.push(bytes))?;
        data_writer.finish()?;
        for pending in small {
            self.sim.finish_write(pending)?;
        }
        self.sim.finish_write(data)?;
        if self.sync_on_save {
            // §III-C: "we perform disk synchronization ... immediately
            // after Saver returns".
            self.sim.syncfs(&handle.device)?;
        }
        // Register residency (fires write-through drains + capacity
        // pressure on the landing tier).
        if let (Some(hier), Some(t)) = (&self.route, tier) {
            let keys: Vec<String> =
                handle.files().iter().map(|f| f.rel.clone()).collect();
            hier.note_written(&keys, t)?;
        }
        self.saved.push(handle.clone());
        self.cleanup()?;
        Ok(handle)
    }

    /// Retention: keep only the newest `max_to_keep` checkpoints.
    /// Victims vetoed by the retention guard stay until a later pass.
    /// Routed savers remove only the landing tier's copies — archive
    /// copies a hierarchy drained to slower tiers survive retention
    /// (exactly the burst buffer's staged-vs-archived split).
    fn cleanup(&mut self) -> Result<()> {
        while self.saved.len() > self.max_to_keep {
            if let Some(guard) = &self.retention_guard {
                if !guard(&self.saved[0]) {
                    break;
                }
            }
            let victim = self.saved.remove(0);
            for f in victim.files() {
                let routed_tier = self
                    .route
                    .as_ref()
                    .and_then(|h| h.tier_of_device(&f.device));
                match (&self.route, routed_tier) {
                    (Some(hier), Some(t)) => {
                        hier.remove_from_tier(&f.rel, t)?;
                    }
                    _ => {
                        if self.sim.exists(&f) {
                            self.sim.remove(&f)?;
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Re-run retention (the burst buffer calls this after drains
    /// complete, when the guard's vetoes have lifted).
    pub fn sweep_retention(&mut self) -> Result<()> {
        self.cleanup()
    }

    /// Checkpoints currently retained, oldest first.
    pub fn retained(&self) -> &[CheckpointHandle] {
        &self.saved
    }

    /// Restore a state from a handle (graph meta first, then
    /// variables — the order §II-B describes).
    pub fn restore(
        sim: &StorageSim,
        profile: &ProfileMeta,
        handle: &CheckpointHandle,
    ) -> Result<ModelState> {
        let meta_bytes = sim
            .read(&handle.file("meta"))
            .context("reading checkpoint .meta")?;
        let meta = Json::parse(std::str::from_utf8(&meta_bytes)?)
            .context("parsing checkpoint .meta")?;
        let saved_profile = meta
            .get("profile")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!(".meta missing profile"))?;
        if saved_profile != profile.name {
            return Err(anyhow!(
                "checkpoint is for profile {saved_profile:?}, \
                 trainer uses {:?}", profile.name
            ));
        }
        let n_meta = meta
            .get("params")
            .and_then(Json::as_arr)
            .map(|a| a.len())
            .unwrap_or(0);
        if n_meta != profile.params.len() {
            return Err(anyhow!(
                ".meta has {n_meta} tensors, profile has {}",
                profile.params.len()
            ));
        }
        let index_bytes = sim
            .read(&handle.file("index"))
            .context("reading checkpoint .index")?;
        let data = sim
            .read(&handle.file("data"))
            .context("reading checkpoint .data")?;
        // Check every tensor's (offset, len) against the profile's
        // layout before trusting the payload.
        validate_index(&index_bytes, profile, data.len() as u64)
            .with_context(|| format!("validating {}", handle.file("index")))?;
        let state = ModelState::from_bytes(profile, &data)?;
        state.validate(profile)?;
        Ok(state)
    }

    /// Find the latest checkpoint under `device://dir` with `prefix`.
    pub fn latest(
        sim: &StorageSim,
        device: &str,
        prefix: &str,
    ) -> Result<Option<CheckpointHandle>> {
        let dir = match prefix.rsplit_once('/') {
            Some((d, _)) => d,
            None => "",
        };
        let mut best: Option<CheckpointHandle> = None;
        for p in sim.list(device, dir)? {
            if let Some(rest) = p
                .rel
                .strip_prefix(&format!("{prefix}-"))
                .and_then(|r| r.strip_suffix(".data"))
            {
                if let Ok(step) = rest.parse::<u64>() {
                    if best.as_ref().map_or(true, |b| step > b.step) {
                        best = Some(CheckpointHandle {
                            device: device.to_string(),
                            prefix: prefix.to_string(),
                            step,
                        });
                    }
                }
            }
        }
        Ok(best)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::meta::ParamSpec;
    use crate::storage::DeviceModel;

    fn fast_model(name: &str) -> DeviceModel {
        DeviceModel {
            name: name.into(),
            read_bw: 1e9,
            write_bw: 1e9,
            read_lat: 0.0,
            write_lat: 0.0,
            channels: 4,
            elevator: vec![(1, 1.0)],
            time_scale: 1000.0,
            lat_tables: None,
        }
    }

    fn sim(tag: &str) -> Arc<StorageSim> {
        let dir = std::env::temp_dir()
            .join(format!("dlio-saver-test-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        Arc::new(StorageSim::cold(dir, vec![fast_model("ssd")]).unwrap())
    }

    fn profile() -> ProfileMeta {
        ProfileMeta {
            name: "t".into(),
            input_size: 8,
            num_classes: 4,
            num_params: 4 * 3 + 3,
            params: vec![
                ParamSpec { name: "fc1/kernel".into(), shape: vec![4, 3] },
                ParamSpec { name: "fc1/bias".into(), shape: vec![3] },
            ],
        }
    }

    #[test]
    fn streamed_data_matches_contiguous_serialization() {
        let sim = sim("streamed");
        let profile = profile();
        let mut state = ModelState::init(&profile, 11);
        state.step = 5.0;
        state.m[0][3] = 0.75;
        let mut saver =
            Saver::new(Arc::clone(&sim), profile.clone(), "ssd", "ck/m", 5);
        saver.sync_on_save = false;
        let h = saver.save(&state, 5).unwrap();
        // The streamed .data payload is bit-identical to to_bytes().
        let on_disk = sim.read(&h.file("data")).unwrap();
        assert_eq!(on_disk, state.to_bytes());
        let back = Saver::restore(&sim, &profile, &h).unwrap();
        assert_eq!(back.params, state.params);
        assert_eq!(back.m, state.m);
        assert_eq!(back.step, 5.0);
    }

    #[test]
    fn restore_rejects_corrupted_index() {
        let sim = sim("corrupt-index");
        let profile = profile();
        let state = ModelState::init(&profile, 1);
        let mut saver =
            Saver::new(Arc::clone(&sim), profile.clone(), "ssd", "ck/m", 5);
        saver.sync_on_save = false;
        let h = saver.save(&state, 1).unwrap();

        // Garbage bytes: must fail to parse.
        sim.write(&h.file("index"), b"not json at all").unwrap();
        assert!(Saver::restore(&sim, &profile, &h).is_err());

        // Valid JSON with a wrong offset: must fail validation.
        let good = {
            let s2 =
                Saver::new(Arc::clone(&sim), profile.clone(), "ssd", "x/x", 5);
            s2.index_json()
        };
        let tampered = good.replace("\"offset\":48", "\"offset\":52");
        assert_ne!(tampered, good, "tamper target must exist in the index");
        sim.write(&h.file("index"), tampered.as_bytes()).unwrap();
        assert!(Saver::restore(&sim, &profile, &h).is_err());

        // Restoring the correct index heals the checkpoint.
        sim.write(&h.file("index"), good.as_bytes()).unwrap();
        assert!(Saver::restore(&sim, &profile, &h).is_ok());
    }

    #[test]
    fn restore_rejects_truncated_data() {
        let sim = sim("short-data");
        let profile = profile();
        let state = ModelState::init(&profile, 2);
        let mut saver =
            Saver::new(Arc::clone(&sim), profile.clone(), "ssd", "ck/m", 5);
        saver.sync_on_save = false;
        let h = saver.save(&state, 1).unwrap();
        let mut data = sim.read(&h.file("data")).unwrap();
        data.truncate(data.len() - 4);
        sim.write(&h.file("data"), &data).unwrap();
        assert!(Saver::restore(&sim, &profile, &h).is_err());
    }

    #[test]
    fn retention_guard_defers_cleanup() {
        use std::sync::atomic::{AtomicBool, Ordering};
        let sim = sim("guard");
        let profile = profile();
        let state = ModelState::init(&profile, 3);
        let mut saver =
            Saver::new(Arc::clone(&sim), profile.clone(), "ssd", "ck/m", 1);
        saver.sync_on_save = false;
        let allow = Arc::new(AtomicBool::new(false));
        let allow2 = Arc::clone(&allow);
        saver.set_retention_guard(Arc::new(move |_h| {
            allow2.load(Ordering::SeqCst)
        }));
        let h1 = saver.save(&state, 1).unwrap();
        let _h2 = saver.save(&state, 2).unwrap();
        // Guard vetoes: the over-quota checkpoint survives.
        assert_eq!(saver.retained().len(), 2);
        assert!(sim.exists(&h1.file("data")));
        // Guard lifts: sweep deletes it.
        allow.store(true, Ordering::SeqCst);
        saver.sweep_retention().unwrap();
        assert_eq!(saver.retained().len(), 1);
        assert!(!sim.exists(&h1.file("data")));
    }
}
