//! Checkpointing (§II-B, §III-C): a `tf.train.Saver` work-alike plus
//! the paper's proof-of-concept burst buffer.

pub mod burst_buffer;
pub mod saver;

pub use burst_buffer::BurstBuffer;
pub use saver::{CheckpointHandle, Saver};
