//! `dlio qos-sweep` — the adaptive-QoS characterization driver.
//!
//! Runs a matrix of (scheduler mode × checkpoint interval × reader
//! shards) cells over the microbench-style ingest workload with
//! periodic checkpoint bursts, and reports each cell's per-class
//! queue-depth/latency numbers straight from `EngineDeviceStats` —
//! the Fig. 4/8-style curves (per-class time-resolved I/O, as
//! tf-Darshan plots them) that EXPERIMENTS.md used to describe as a
//! hand-run recipe.
//!
//! Every cell is self-contained: a fresh sim (fresh scheduler state)
//! over a shared on-disk corpus, `IoEngine::reset_stats` bracketing
//! the measured phase so fixture setup never pollutes the curves.
//! Output is one CSV/JSON row per cell.

use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use crate::config::Testbed;
use crate::data::manifest::Sample;
use crate::pipeline::{sharded_reader, Dataset};
use crate::storage::{
    ClassStats, ClockSpec, IoClass, IoRequest, IoTicket, QosConfig, SimPath,
    StorageSim,
};
use crate::util::json::{obj, to_string, Json};

/// Sweep matrix + workload shape.
#[derive(Debug, Clone)]
pub struct QosSweepConfig {
    /// Device profile the cells run against.
    pub device: String,
    /// Scheduler modes: `fifo` | `static` | `adaptive`.
    pub modes: Vec<String>,
    /// Checkpoint burst every N ingest batches (0 = no checkpoints).
    pub intervals: Vec<usize>,
    /// Reader shard counts.
    pub shards: Vec<usize>,
    /// Corpus size, files.
    pub files: usize,
    /// Bytes per corpus file.
    pub file_bytes: usize,
    /// Per-shard in-flight read window.
    pub window: usize,
    /// Images consumed per batch.
    pub batch: usize,
    /// Checkpoint writes per burst.
    pub ckpt_writes: usize,
    /// Bytes per checkpoint write.
    pub ckpt_bytes: u64,
    /// Ingest p99 queue-wait target for the adaptive mode, modelled
    /// seconds.
    pub adaptive_target: f64,
    /// Simulation speed-up (devices run `time_scale`x the modelled
    /// speed; reported latencies are wall — scale back to compare
    /// against modelled targets).
    pub time_scale: f64,
    /// Working directory root (each cell gets a subdirectory).
    pub workdir: String,
    /// Time source per cell.  Virtual (the default) runs each cell in
    /// discrete-event time: identical modelled durations, no sleeping,
    /// so the full matrix finishes orders of magnitude faster.
    pub clock: ClockSpec,
}

impl QosSweepConfig {
    /// Full default matrix: 3 modes x 3 intervals x 3 shard counts.
    pub fn standard(workdir: String, time_scale: f64) -> QosSweepConfig {
        QosSweepConfig {
            device: "hdd".into(),
            modes: vec!["fifo".into(), "static".into(), "adaptive".into()],
            intervals: vec![0, 2, 8],
            shards: vec![1, 2, 4],
            files: 96,
            file_bytes: 64 * 1024,
            window: 4,
            batch: 16,
            ckpt_writes: 4,
            ckpt_bytes: 2_000_000,
            adaptive_target: 0.005,
            time_scale,
            workdir,
            clock: ClockSpec::Virtual,
        }
    }

    /// Tiny matrix for CI: 3 modes x 1 interval x 2 shard counts on
    /// the (low-latency) SSD profile — seconds, not minutes.
    pub fn smoke(workdir: String, time_scale: f64) -> QosSweepConfig {
        QosSweepConfig {
            device: "ssd".into(),
            modes: vec!["fifo".into(), "static".into(), "adaptive".into()],
            intervals: vec![2],
            shards: vec![1, 2],
            files: 32,
            file_bytes: 16 * 1024,
            window: 4,
            batch: 8,
            ckpt_writes: 2,
            ckpt_bytes: 1_000_000,
            adaptive_target: 0.005,
            time_scale,
            workdir,
            clock: ClockSpec::Virtual,
        }
    }

    /// Resolve a mode name to the scheduler config it denotes (the
    /// shared `QosConfig::parse_mode` map, with this sweep's adaptive
    /// target).
    pub fn qos_for(&self, mode: &str) -> Result<QosConfig> {
        QosConfig::parse_mode(mode, self.adaptive_target)
    }
}

/// Per-class slice of a cell row (wall seconds converted to ms).
#[derive(Debug, Clone, Default)]
pub struct ClassRow {
    pub completed: u64,
    pub max_queue_depth: u32,
    pub mean_queue_ms: f64,
    pub p99_queue_ms: f64,
    pub mean_service_ms: f64,
    pub mbytes: f64,
}

impl ClassRow {
    fn from_stats(c: &ClassStats) -> ClassRow {
        ClassRow {
            completed: c.completed,
            max_queue_depth: c.max_queue_depth,
            mean_queue_ms: c.mean_queue_secs() * 1e3,
            p99_queue_ms: c.p99_queue_secs() * 1e3,
            mean_service_ms: c.mean_service_secs() * 1e3,
            mbytes: (c.bytes_read + c.bytes_written) as f64 / 1e6,
        }
    }
}

/// One (mode, interval, shards) cell of the sweep.
#[derive(Debug, Clone)]
pub struct QosSweepCell {
    pub mode: String,
    pub interval: usize,
    pub shards: usize,
    pub device: String,
    pub images: u64,
    pub elapsed_secs: f64,
    pub images_per_sec: f64,
    pub ingest: ClassRow,
    pub checkpoint: ClassRow,
    /// Effective Ingest DRR weight at the end of the cell (static
    /// weight unless the adaptive controller moved it).
    pub ingest_weight: u32,
    /// Points in the adaptive controller's weight trajectory.
    pub weight_changes: usize,
}

/// CSV column order — kept in one place so the header and the row
/// writer can never drift apart.
const CSV_COLUMNS: [&str; 19] = [
    "mode",
    "interval",
    "shards",
    "device",
    "images",
    "elapsed_secs",
    "images_per_sec",
    "ingest_completed",
    "ingest_max_qdepth",
    "ingest_mean_queue_ms",
    "ingest_p99_queue_ms",
    "ingest_mean_svc_ms",
    "ingest_mb",
    "ckpt_completed",
    "ckpt_max_qdepth",
    "ckpt_mean_queue_ms",
    "ckpt_p99_queue_ms",
    "ckpt_mb",
    "ingest_weight",
];

impl QosSweepCell {
    fn csv_row(&self) -> String {
        [
            self.mode.clone(),
            self.interval.to_string(),
            self.shards.to_string(),
            self.device.clone(),
            self.images.to_string(),
            format!("{:.4}", self.elapsed_secs),
            format!("{:.1}", self.images_per_sec),
            self.ingest.completed.to_string(),
            self.ingest.max_queue_depth.to_string(),
            format!("{:.4}", self.ingest.mean_queue_ms),
            format!("{:.4}", self.ingest.p99_queue_ms),
            format!("{:.4}", self.ingest.mean_service_ms),
            format!("{:.2}", self.ingest.mbytes),
            self.checkpoint.completed.to_string(),
            self.checkpoint.max_queue_depth.to_string(),
            format!("{:.4}", self.checkpoint.mean_queue_ms),
            format!("{:.4}", self.checkpoint.p99_queue_ms),
            format!("{:.2}", self.checkpoint.mbytes),
            self.ingest_weight.to_string(),
        ]
        .join(",")
    }

    fn json_value(&self) -> Json {
        let class = |c: &ClassRow| {
            obj(vec![
                ("completed", Json::Num(c.completed as f64)),
                ("max_qdepth", Json::Num(c.max_queue_depth as f64)),
                ("mean_queue_ms", Json::Num(c.mean_queue_ms)),
                ("p99_queue_ms", Json::Num(c.p99_queue_ms)),
                ("mean_svc_ms", Json::Num(c.mean_service_ms)),
                ("mb", Json::Num(c.mbytes)),
            ])
        };
        obj(vec![
            ("mode", Json::Str(self.mode.clone())),
            ("interval", Json::Num(self.interval as f64)),
            ("shards", Json::Num(self.shards as f64)),
            ("device", Json::Str(self.device.clone())),
            ("images", Json::Num(self.images as f64)),
            ("elapsed_secs", Json::Num(self.elapsed_secs)),
            ("images_per_sec", Json::Num(self.images_per_sec)),
            ("ingest", class(&self.ingest)),
            ("checkpoint", class(&self.checkpoint)),
            ("ingest_weight", Json::Num(self.ingest_weight as f64)),
            ("weight_changes", Json::Num(self.weight_changes as f64)),
        ])
    }
}

/// Render cells as CSV (header + one line per cell).
pub fn to_csv(cells: &[QosSweepCell]) -> String {
    let mut out = CSV_COLUMNS.join(",");
    out.push('\n');
    for c in cells {
        out.push_str(&c.csv_row());
        out.push('\n');
    }
    out
}

/// Render cells as a JSON array (one object per cell).
pub fn to_json(cells: &[QosSweepCell]) -> String {
    to_string(&Json::Arr(cells.iter().map(|c| c.json_value()).collect()))
}

/// Run the full matrix; cells come back in (mode, interval, shards)
/// iteration order.
pub fn run(cfg: &QosSweepConfig) -> Result<Vec<QosSweepCell>> {
    let mut cells = Vec::new();
    for mode in &cfg.modes {
        for &interval in &cfg.intervals {
            for &shards in &cfg.shards {
                cells.push(run_cell(cfg, mode, interval, shards)?);
            }
        }
    }
    Ok(cells)
}

/// Device model for the configured profile name, at the sweep's time
/// scale.
fn device_model(cfg: &QosSweepConfig) -> Result<crate::storage::DeviceModel> {
    Testbed::paper(cfg.time_scale)
        .devices
        .into_iter()
        .find(|m| m.name == cfg.device)
        .ok_or_else(|| anyhow!("unknown device {:?}", cfg.device))
}

fn run_cell(
    cfg: &QosSweepConfig,
    mode: &str,
    interval: usize,
    shards: usize,
) -> Result<QosSweepCell> {
    let qos = cfg.qos_for(mode)?;
    // Record the canonical scheduler-mode label, not the raw token:
    // the two can only agree because qos_for is the name→config map,
    // and this keeps the output honest if that map ever grows.
    let mode = qos.mode_name();
    let dir = std::path::Path::new(&cfg.workdir)
        .join(format!("qos-sweep-{mode}-i{interval}-s{shards}"));
    let _ = std::fs::remove_dir_all(&dir);
    let clock = cfg.clock.build();
    let sim = Arc::new(StorageSim::cold_with_qos_clock(
        dir,
        vec![device_model(cfg)?],
        qos,
        clock.clone(),
    )?);

    // Fixture: the ingest corpus, written through the sim (so backing
    // files exist), then excluded from the measured stats.
    let samples: Vec<Sample> = (0..cfg.files)
        .map(|i| -> Result<Sample> {
            let p = SimPath::new(&cfg.device, format!("corpus/f{i}.bin"));
            sim.write(&p, &vec![(i % 251) as u8; cfg.file_bytes])?;
            Ok(Sample { path: p, label: i as u32 })
        })
        .collect::<Result<_>>()?;
    sim.drop_caches();
    sim.engine().reset_stats();

    // Register the cell driver: virtual time advances only while this
    // thread blocks on tickets, so submissions are instantaneous in
    // modelled time and the cell is deterministic.
    let _reg = clock.enter();

    // Measured phase: sharded ingest with a checkpoint burst every
    // `interval` batches (the paper's §V contention pattern).
    let mut ds =
        sharded_reader(samples, Arc::clone(&sim), shards, cfg.window);
    let mut ckpt_tickets: Vec<IoTicket> = Vec::new();
    let mut images = 0u64;
    let mut batch_idx = 0usize;
    // batch = 0 would never call ds.next(), so the loop below would
    // spin submitting checkpoint bursts forever: clamp like the
    // reader clamps shards/window.
    let batch = cfg.batch.max(1);
    let t0 = clock.now();
    'outer: loop {
        for _ in 0..batch {
            match ds.next() {
                Some(item) => {
                    item.context("sweep ingest read failed")?;
                    images += 1;
                }
                None => break 'outer,
            }
        }
        batch_idx += 1;
        if interval > 0 && batch_idx % interval == 0 {
            for _ in 0..cfg.ckpt_writes {
                ckpt_tickets.push(sim.engine().submit(
                    IoRequest::ProbeWrite {
                        device: cfg.device.clone(),
                        bytes: cfg.ckpt_bytes,
                    },
                )?);
            }
        }
    }
    // Stop the ingest clock *before* draining the checkpoint
    // backlog: adaptive/static exist to defer checkpoint service, so
    // charging their larger undrained backlog to elapsed_secs would
    // deflate images_per_sec for exactly the modes that protected
    // ingest (inverting the comparison this tool emits).  The drain
    // still completes below so the checkpoint class rows are final.
    let elapsed = clock.now() - t0;
    for t in ckpt_tickets {
        t.wait()?;
    }

    let stats = sim.engine().stats();
    let s = stats
        .iter()
        .find(|s| s.device == cfg.device)
        .ok_or_else(|| anyhow!("no stats for device {:?}", cfg.device))?;
    Ok(QosSweepCell {
        mode: mode.to_string(),
        interval,
        shards,
        device: cfg.device.clone(),
        images,
        elapsed_secs: elapsed,
        images_per_sec: if elapsed > 0.0 {
            images as f64 / elapsed
        } else {
            0.0
        },
        ingest: ClassRow::from_stats(s.class(IoClass::Ingest)),
        checkpoint: ClassRow::from_stats(s.class(IoClass::Checkpoint)),
        ingest_weight: s.ingest_weight,
        weight_changes: s.weight_trajectory.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg(tag: &str) -> QosSweepConfig {
        let dir = std::env::temp_dir()
            .join(format!("dlio-qos-sweep-test-{tag}-{}", std::process::id()));
        QosSweepConfig {
            device: "ssd".into(),
            modes: vec!["static".into(), "adaptive".into()],
            intervals: vec![1],
            shards: vec![2],
            files: 12,
            file_bytes: 4 * 1024,
            window: 2,
            batch: 4,
            ckpt_writes: 1,
            ckpt_bytes: 100_000,
            adaptive_target: 0.005,
            time_scale: 1000.0,
            workdir: dir.to_string_lossy().into_owned(),
            clock: ClockSpec::Virtual,
        }
    }

    #[test]
    fn sweep_emits_one_row_per_cell_with_sane_fields() {
        let cfg = tiny_cfg("rows");
        let cells = run(&cfg).unwrap();
        assert_eq!(cells.len(), 2); // 2 modes x 1 interval x 1 shard
        for c in &cells {
            assert_eq!(c.images, 12, "every sample read exactly once");
            assert_eq!(c.ingest.completed, 12);
            // 12 images / batch 4 = 3 batches, a burst after each.
            assert_eq!(c.checkpoint.completed, 3);
            assert!(c.elapsed_secs > 0.0);
            assert!(c.ingest_weight >= 1);
        }
        // CSV: header + one line per cell, constant column count.
        let csv = to_csv(&cells);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 3);
        let ncols = lines[0].split(',').count();
        for l in &lines {
            assert_eq!(l.split(',').count(), ncols, "ragged CSV: {l}");
        }
        // JSON round-trips through the in-repo parser.
        let parsed = Json::parse(&to_json(&cells)).unwrap();
        match parsed {
            Json::Arr(rows) => {
                assert_eq!(rows.len(), 2);
                for r in rows {
                    assert!(r.get("mode").and_then(Json::as_str).is_some());
                    assert!(r.get("ingest").is_some());
                }
            }
            other => panic!("expected a JSON array, got {other:?}"),
        }
    }

    #[test]
    fn unknown_mode_is_rejected() {
        let mut cfg = tiny_cfg("badmode");
        cfg.modes = vec!["banana".into()];
        assert!(run(&cfg).is_err());
    }
}
