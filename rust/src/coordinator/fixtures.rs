//! Shared experiment fixtures: build the testbed sim and (re)generate
//! corpora, reusing backing files across runs when they match.

use std::sync::Arc;

use anyhow::Result;

use crate::config::Testbed;
use crate::data::{generator, CorpusSpec, Manifest};
use crate::storage::{IoObserver, NullObserver, StorageSim};

/// Instantiate the testbed's storage sim (optionally traced).
pub fn make_sim(testbed: &Testbed, observer: Option<Arc<dyn IoObserver>>)
    -> Result<Arc<StorageSim>>
{
    let obs = observer.unwrap_or_else(|| Arc::new(NullObserver));
    Ok(Arc::new(StorageSim::with_qos(
        testbed.workdir.clone(),
        testbed.devices.clone(),
        testbed.cache_bytes,
        obs,
        testbed.qos.clone(),
    )?))
}

/// Ensure `spec` exists on `device`, generating it only when the
/// on-disk manifest doesn't match (corpus generation is fixture setup
/// and can dominate bench start-up otherwise).
pub fn ensure_corpus(
    sim: &StorageSim,
    device: &str,
    spec: &CorpusSpec,
) -> Result<Manifest> {
    if let Ok(m) = generator::load_manifest(sim, device, &spec.name) {
        if m.len() == spec.num_files
            && m.num_classes == spec.num_classes
            && m.src_size == spec.src_size
            && m.samples
                .first()
                .map_or(true, |s| sim.exists(&s.path))
            && m.samples
                .last()
                .map_or(true, |s| sim.exists(&s.path))
        {
            return Ok(m);
        }
    }
    generator::generate(sim, device, spec)
}

/// Mirror one corpus onto several devices (the paper repeats tests
/// "with sample images placed on different devices").  Backing bytes
/// are hard-linked when possible to save space/time.
pub fn ensure_corpus_on_devices(
    sim: &StorageSim,
    devices: &[&str],
    spec: &CorpusSpec,
) -> Result<Vec<Manifest>> {
    let mut out = Vec::new();
    for dev in devices {
        out.push(ensure_corpus(sim, dev, spec)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::DeviceModel;

    fn testbed(tag: &str) -> Testbed {
        let dir = std::env::temp_dir()
            .join(format!("dlio-fix-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        Testbed {
            devices: vec![DeviceModel {
                name: "ssd".into(),
                read_bw: 1e9,
                write_bw: 1e9,
                read_lat: 0.0,
                write_lat: 0.0,
                channels: 8,
                elevator: vec![(1, 1.0)],
                time_scale: 1000.0,
            }],
            cache_bytes: 0,
            workdir: dir.to_string_lossy().into_owned(),
            qos: crate::storage::QosConfig::default(),
        }
    }

    #[test]
    fn corpus_cached_across_calls() {
        let tb = testbed("cache");
        let sim = make_sim(&tb, None).unwrap();
        let spec = CorpusSpec {
            name: "c".into(),
            num_files: 10,
            num_classes: 4,
            src_size: 32,
            median_bytes: 4096,
            sigma: 0.2,
            corrupt_frac: 0.0,
            seed: 1,
        };
        let t0 = std::time::Instant::now();
        let m1 = ensure_corpus(&sim, "ssd", &spec).unwrap();
        let first = t0.elapsed();
        let t0 = std::time::Instant::now();
        let m2 = ensure_corpus(&sim, "ssd", &spec).unwrap();
        let second = t0.elapsed();
        assert_eq!(m1.samples, m2.samples);
        assert!(second < first, "{second:?} !< {first:?}");
    }

    #[test]
    fn spec_change_regenerates() {
        let tb = testbed("regen");
        let sim = make_sim(&tb, None).unwrap();
        let mut spec = CorpusSpec {
            name: "c".into(),
            num_files: 5,
            num_classes: 4,
            src_size: 32,
            median_bytes: 4096,
            sigma: 0.2,
            corrupt_frac: 0.0,
            seed: 1,
        };
        let m1 = ensure_corpus(&sim, "ssd", &spec).unwrap();
        spec.num_files = 8;
        let m2 = ensure_corpus(&sim, "ssd", &spec).unwrap();
        assert_eq!(m1.len(), 5);
        assert_eq!(m2.len(), 8);
    }
}
