//! Shared experiment fixtures: build the testbed sim and (re)generate
//! corpora, reusing backing files across runs when they match.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::config::Testbed;
use crate::data::{generator, CorpusSpec, Manifest};
use crate::storage::{
    policy, profiles, IoObserver, NullObserver, StorageHierarchy,
    StorageSim, TierKind,
};

/// Instantiate the testbed's storage sim (optionally traced).
pub fn make_sim(testbed: &Testbed, observer: Option<Arc<dyn IoObserver>>)
    -> Result<Arc<StorageSim>>
{
    let obs = observer.unwrap_or_else(|| Arc::new(NullObserver));
    Ok(Arc::new(StorageSim::with_qos(
        testbed.workdir.clone(),
        testbed.devices.clone(),
        testbed.cache_bytes,
        obs,
        testbed.qos.clone(),
    )?))
}

/// Ensure `spec` exists on `device`, generating it only when the
/// on-disk manifest doesn't match (corpus generation is fixture setup
/// and can dominate bench start-up otherwise).
pub fn ensure_corpus(
    sim: &StorageSim,
    device: &str,
    spec: &CorpusSpec,
) -> Result<Manifest> {
    if let Ok(m) = generator::load_manifest(sim, device, &spec.name) {
        if m.len() == spec.num_files
            && m.num_classes == spec.num_classes
            && m.src_size == spec.src_size
            && m.samples
                .first()
                .map_or(true, |s| sim.exists(&s.path))
            && m.samples
                .last()
                .map_or(true, |s| sim.exists(&s.path))
        {
            return Ok(m);
        }
    }
    generator::generate(sim, device, spec)
}

/// Mirror one corpus onto several devices (the paper repeats tests
/// "with sample images placed on different devices").  Backing bytes
/// are hard-linked when possible to save space/time.
pub fn ensure_corpus_on_devices(
    sim: &StorageSim,
    devices: &[&str],
    spec: &CorpusSpec,
) -> Result<Vec<Manifest>> {
    let mut out = Vec::new();
    for dev in devices {
        out.push(ensure_corpus(sim, dev, spec)?);
    }
    Ok(out)
}

/// A parsed `--device` value: a flat device name, or `hier:<preset>`
/// routing a single-job run through the storage hierarchy (DESIGN.md
/// §12) instead of straight at one device.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StorageTarget {
    /// Plain device name ("ssd", "hdd", ...).
    Flat(String),
    /// Hierarchy preset name (`profiles::HIERARCHY_NAMES`).
    Hier(String),
}

impl StorageTarget {
    pub fn parse(raw: &str) -> StorageTarget {
        match raw.strip_prefix("hier:") {
            Some(p) => StorageTarget::Hier(p.to_string()),
            None => StorageTarget::Flat(raw.to_string()),
        }
    }
}

/// Build a named hierarchy preset over `sim` (noop placement — the
/// single-job CLI path characterizes tiering, not promotion) and
/// return it with its bottom device tier's device name.  The corpus
/// is homed there, so reads enter at the slow tier exactly like the
/// tier-sweep cells and residency auto-registers on first access.
pub fn build_hierarchy(
    sim: &Arc<StorageSim>,
    preset: &str,
) -> Result<(Arc<StorageHierarchy>, String)> {
    build_hierarchy_with_policy(sim, preset, "noop")
}

/// [`build_hierarchy`] with an explicit placement policy
/// (`--policy` on the CLI paths): lets a single `hier:` run exercise
/// promotion/demotion and report the policy's decision counters.
pub fn build_hierarchy_with_policy(
    sim: &Arc<StorageSim>,
    preset: &str,
    policy_name: &str,
) -> Result<(Arc<StorageHierarchy>, String)> {
    let spec = profiles::hierarchy_by_name(preset).ok_or_else(|| {
        anyhow!(
            "unknown hierarchy {preset:?} (valid: {})",
            profiles::HIERARCHY_NAMES.join(", ")
        )
    })?;
    let bottom = spec
        .tiers
        .iter()
        .rev()
        .find_map(|t| match &t.kind {
            TierKind::Device(d) => Some(d.clone()),
            _ => None,
        })
        .ok_or_else(|| {
            anyhow!("hierarchy {preset:?} has no device tier")
        })?;
    let hier = Arc::new(StorageHierarchy::new(
        Arc::clone(sim),
        spec,
        policy::by_name(policy_name)?,
    )?);
    Ok((hier, bottom))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::DeviceModel;

    fn testbed(tag: &str) -> Testbed {
        let dir = std::env::temp_dir()
            .join(format!("dlio-fix-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        Testbed {
            devices: vec![DeviceModel {
                name: "ssd".into(),
                read_bw: 1e9,
                write_bw: 1e9,
                read_lat: 0.0,
                write_lat: 0.0,
                channels: 8,
                elevator: vec![(1, 1.0)],
                time_scale: 1000.0,
                lat_tables: None,
            }],
            cache_bytes: 0,
            workdir: dir.to_string_lossy().into_owned(),
            qos: crate::storage::QosConfig::default(),
        }
    }

    #[test]
    fn corpus_cached_across_calls() {
        let tb = testbed("cache");
        let sim = make_sim(&tb, None).unwrap();
        let spec = CorpusSpec {
            name: "c".into(),
            num_files: 10,
            num_classes: 4,
            src_size: 32,
            median_bytes: 4096,
            sigma: 0.2,
            corrupt_frac: 0.0,
            seed: 1,
        };
        let t0 = std::time::Instant::now();
        let m1 = ensure_corpus(&sim, "ssd", &spec).unwrap();
        let first = t0.elapsed();
        let t0 = std::time::Instant::now();
        let m2 = ensure_corpus(&sim, "ssd", &spec).unwrap();
        let second = t0.elapsed();
        assert_eq!(m1.samples, m2.samples);
        assert!(second < first, "{second:?} !< {first:?}");
    }

    #[test]
    fn spec_change_regenerates() {
        let tb = testbed("regen");
        let sim = make_sim(&tb, None).unwrap();
        let mut spec = CorpusSpec {
            name: "c".into(),
            num_files: 5,
            num_classes: 4,
            src_size: 32,
            median_bytes: 4096,
            sigma: 0.2,
            corrupt_frac: 0.0,
            seed: 1,
        };
        let m1 = ensure_corpus(&sim, "ssd", &spec).unwrap();
        spec.num_files = 8;
        let m2 = ensure_corpus(&sim, "ssd", &spec).unwrap();
        assert_eq!(m1.len(), 5);
        assert_eq!(m2.len(), 8);
    }

    #[test]
    fn storage_target_parses_flat_and_hier() {
        assert_eq!(
            StorageTarget::parse("ssd"),
            StorageTarget::Flat("ssd".into())
        );
        assert_eq!(
            StorageTarget::parse("hier:blackdog-bb"),
            StorageTarget::Hier("blackdog-bb".into())
        );
    }

    #[test]
    fn hier_target_routes_reads_through_the_hierarchy() {
        // Smoke test for the `hier:<preset>` CLI path: corpus homed
        // on the preset's bottom device, reads served by the
        // hierarchy (auto-registered residency).
        let dir = std::env::temp_dir()
            .join(format!("dlio-fix-hier-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut tb = Testbed::paper(1000.0);
        tb.workdir = dir.to_string_lossy().into_owned();
        let sim = make_sim(&tb, None).unwrap();
        let err =
            build_hierarchy(&sim, "floppy").unwrap_err().to_string();
        assert!(
            err.contains("blackdog-bb")
                && err.contains("tegner-lustre+optane"),
            "hierarchy error does not list valid presets: {err}"
        );
        let (hier, bottom) =
            build_hierarchy(&sim, "blackdog-bb").unwrap();
        assert_eq!(bottom, "hdd", "bb preset drains to hdd");
        let spec = CorpusSpec {
            name: "hier-smoke".into(),
            num_files: 8,
            num_classes: 2,
            src_size: 32,
            median_bytes: 2048,
            sigma: 0.2,
            corrupt_frac: 0.0,
            seed: 3,
        };
        let m = ensure_corpus(&sim, &bottom, &spec).unwrap();
        sim.drop_caches();
        let ds = crate::pipeline::sharded_reader_hier(
            m.samples.clone(),
            Arc::clone(&hier),
            2,
            2,
        );
        let out = crate::pipeline::collect(ds).unwrap();
        assert_eq!(out.len(), 8);
        assert_eq!(hier.total_reads(), 8);
    }
}
