//! `dlio trace-record` — run a workload with the request-level
//! recorder attached and write the trace file (DESIGN.md §11).
//!
//! Workloads mirror the paper's studies without needing PJRT
//! artifacts:
//!
//! * `microbench` — fixed-seed sharded ingest reads over a synthetic
//!   corpus with periodic checkpoint bursts (the §V contention
//!   pattern behind Figs. 4/8).
//! * `miniapp` — same ingest, but each burst writes real checkpoint
//!   files on the primary device and then drains them to the slow
//!   device as Drain-class copies — the burst-buffer Fig. 10 pattern,
//!   so traces carry all three traffic classes.
//!
//! Corpus generation is fixture setup: the recorder attaches *after*
//! it (and after a stats reset), so a trace holds exactly the
//! measured phase.  Every stochastic choice derives from `cfg.seed`,
//! which is what makes record → closed-loop-replay determinism
//! testable end-to-end.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use crate::config::Testbed;
use crate::data::manifest::Sample;
use crate::pipeline::{sharded_reader, Dataset};
use crate::storage::{
    with_origin, IoClass, IoRequest, IoTicket, PendingWrite, QosConfig,
    SimPath, StorageSim,
};
use crate::trace::{TraceManifest, TraceRecorder, TRACE_VERSION};
use crate::util::Rng;

/// Workload shape for a recording run.
#[derive(Debug, Clone)]
pub struct TraceRecordConfig {
    /// `microbench` | `miniapp`.
    pub workload: String,
    /// Ingest (and checkpoint) device profile name.
    pub device: String,
    /// Drain target for the `miniapp` workload.
    pub drain_device: String,
    /// Corpus size, files.
    pub files: usize,
    /// Bytes per corpus file.
    pub file_bytes: usize,
    /// Reader shards / per-shard in-flight window.
    pub shards: usize,
    pub window: usize,
    /// Images consumed per batch.
    pub batch: usize,
    /// Checkpoint burst every N batches (0 = no bursts).
    pub ckpt_interval: usize,
    /// Writes per burst / bytes per write.
    pub ckpt_writes: usize,
    pub ckpt_bytes: u64,
    /// Shuffle seed (the "fixed-seed" in fixed-seed microbench).
    pub seed: u64,
    /// Simulation speed-up.
    pub time_scale: f64,
    /// Working directory root (the run gets a subdirectory).
    pub workdir: String,
}

impl TraceRecordConfig {
    pub fn standard(workdir: String, time_scale: f64) -> TraceRecordConfig {
        TraceRecordConfig {
            workload: "microbench".into(),
            device: "ssd".into(),
            drain_device: "hdd".into(),
            files: 96,
            file_bytes: 64 * 1024,
            shards: 2,
            window: 4,
            batch: 16,
            ckpt_interval: 2,
            ckpt_writes: 4,
            ckpt_bytes: 2_000_000,
            seed: 7,
            time_scale,
            workdir,
        }
    }

    /// CI-sized run: seconds, not minutes.
    pub fn smoke(workdir: String, time_scale: f64) -> TraceRecordConfig {
        TraceRecordConfig {
            files: 32,
            file_bytes: 16 * 1024,
            batch: 8,
            ckpt_writes: 2,
            ckpt_bytes: 1_000_000,
            ..TraceRecordConfig::standard(workdir, time_scale)
        }
    }
}

/// What a recording run produced.
#[derive(Debug, Clone)]
pub struct TraceRecordResult {
    pub path: PathBuf,
    /// Events written to the trace file.
    pub events: u64,
    /// Ingest reads consumed.
    pub images: u64,
    pub ckpt_bursts: u64,
    /// Drain copies issued (miniapp only).
    pub drains: u64,
    pub elapsed_secs: f64,
}

/// Run `cfg`'s workload under `qos` with the recorder attached;
/// writes the trace to `out`.
pub fn run(
    cfg: &TraceRecordConfig,
    qos: QosConfig,
    out: &Path,
) -> Result<TraceRecordResult> {
    let miniapp = match cfg.workload.as_str() {
        "microbench" => false,
        "miniapp" => true,
        other => {
            return Err(anyhow!(
                "unknown trace-record workload {other:?} \
                 (microbench|miniapp)"
            ))
        }
    };
    if !(cfg.time_scale > 0.0) {
        return Err(anyhow!("time scale must be positive"));
    }
    // Device models: the primary, plus the drain target for miniapp.
    let paper = Testbed::paper(cfg.time_scale).devices;
    let pick = |name: &str| {
        paper
            .iter()
            .find(|m| m.name == name)
            .cloned()
            .ok_or_else(|| anyhow!("unknown device {name:?}"))
    };
    let mut models = vec![pick(&cfg.device)?];
    if miniapp && cfg.drain_device != cfg.device {
        models.push(pick(&cfg.drain_device)?);
    }

    let dir = Path::new(&cfg.workdir)
        .join(format!("trace-record-{}", cfg.workload));
    let _ = std::fs::remove_dir_all(&dir);
    let sim = Arc::new(StorageSim::cold_with_qos(
        dir,
        models.clone(),
        qos.clone(),
    )?);

    // Fixture: corpus + deterministic shuffle, excluded from the trace.
    let mut samples: Vec<Sample> = (0..cfg.files)
        .map(|i| -> Result<Sample> {
            let p = SimPath::new(&cfg.device, format!("corpus/f{i}.bin"));
            sim.write(&p, &vec![(i % 251) as u8; cfg.file_bytes])?;
            Ok(Sample { path: p, label: i as u32 })
        })
        .collect::<Result<_>>()?;
    let mut rng = Rng::new(cfg.seed);
    for i in (1..samples.len()).rev() {
        let j = rng.index(i + 1);
        samples.swap(i, j);
    }
    sim.drop_caches();
    sim.engine().reset_stats();

    let manifest = TraceManifest {
        version: TRACE_VERSION,
        workload: format!(
            "{} device={} files={} file_bytes={} shards={} window={} \
             batch={} ckpt_interval={} ckpt_writes={} ckpt_bytes={} seed={}",
            cfg.workload,
            cfg.device,
            cfg.files,
            cfg.file_bytes,
            cfg.shards,
            cfg.window,
            cfg.batch,
            cfg.ckpt_interval,
            cfg.ckpt_writes,
            cfg.ckpt_bytes,
            cfg.seed,
        ),
        qos_mode: qos.mode_name().to_string(),
        qos: Some(qos.clone()),
        time_scale: cfg.time_scale,
        devices: models,
    };
    let recorder = TraceRecorder::create(out, &manifest)?;
    sim.engine().set_observer(recorder.observer());

    // Measured phase (mirrors the qos-sweep cell workload).
    let timer = crate::metrics::Timer::start();
    let mut ds = sharded_reader(
        samples,
        Arc::clone(&sim),
        cfg.shards.max(1),
        cfg.window.max(1),
    );
    let mut ckpt_tickets: Vec<IoTicket> = Vec::new();
    let mut drains: Vec<PendingWrite> = Vec::new();
    let mut images = 0u64;
    let mut bursts = 0u64;
    let mut drain_count = 0u64;
    let mut batch_idx = 0usize;
    let batch = cfg.batch.max(1);
    'outer: loop {
        for _ in 0..batch {
            match ds.next() {
                Some(item) => {
                    item.context("trace-record ingest read failed")?;
                    images += 1;
                }
                None => break 'outer,
            }
        }
        batch_idx += 1;
        if cfg.ckpt_interval > 0 && batch_idx % cfg.ckpt_interval == 0 {
            bursts += 1;
            if miniapp {
                // Real checkpoint files, then Drain-class copies to
                // the slow device (the Fig. 10 burst-buffer pattern).
                for j in 0..cfg.ckpt_writes {
                    let p = SimPath::new(
                        &cfg.device,
                        format!("ck/b{bursts}-{j}.data"),
                    );
                    with_origin("saver", || {
                        sim.write_class(
                            &p,
                            &vec![0xCD; cfg.ckpt_bytes as usize],
                            IoClass::Checkpoint,
                        )
                    })?;
                    // Distinct archive path: with --drain-device equal
                    // to --device the drain would otherwise be a
                    // self-copy whose writer truncates the file its
                    // reader is mid-way through.
                    let dst = SimPath::new(
                        &cfg.drain_device,
                        format!("archive/{}", p.rel),
                    );
                    drains.push(with_origin("bb-drain", || {
                        sim.copy_async_class(&p, &dst, IoClass::Drain)
                    })?);
                    drain_count += 1;
                }
            } else {
                for _ in 0..cfg.ckpt_writes {
                    ckpt_tickets.push(with_origin("saver", || {
                        sim.engine().submit(IoRequest::ProbeWrite {
                            device: cfg.device.clone(),
                            bytes: cfg.ckpt_bytes,
                        })
                    })?);
                }
            }
        }
    }
    for t in ckpt_tickets {
        t.wait()?;
    }
    for d in drains {
        sim.finish_write(d)?;
    }
    let elapsed_secs = timer.secs();

    sim.engine().clear_observer();
    let events = recorder.finish()?;
    Ok(TraceRecordResult {
        path: out.to_path_buf(),
        events,
        images,
        ckpt_bursts: bursts,
        drains: drain_count,
        elapsed_secs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::IoClass;
    use crate::trace::{replay, ReplayConfig, Trace};

    fn cfg(tag: &str, workload: &str) -> (TraceRecordConfig, PathBuf) {
        let dir = std::env::temp_dir().join(format!(
            "dlio-trace-record-test-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let mut c = TraceRecordConfig::smoke(
            dir.to_string_lossy().into_owned(),
            1000.0,
        );
        c.workload = workload.into();
        c.files = 16;
        c.file_bytes = 8 * 1024;
        c.batch = 4;
        c.ckpt_bytes = 200_000;
        (c, dir.join("trace.jsonl"))
    }

    #[test]
    fn microbench_trace_carries_the_measured_phase_only() {
        let (c, out) = cfg("micro", "microbench");
        let r = run(&c, QosConfig::default(), &out).unwrap();
        assert_eq!(r.images, 16);
        assert_eq!(r.ckpt_bursts, 2); // 16 images / batch 4 / interval 2
        let trace = Trace::load(&out).unwrap();
        assert_eq!(trace.manifest.qos_mode, "static");
        assert!(trace.manifest.workload.contains("microbench"));
        let aggs = trace.recorded_aggregates();
        let ing = &aggs[IoClass::Ingest.index()];
        // Exactly the measured ingest: corpus fixture writes excluded.
        assert_eq!(ing.completed, 16);
        assert_eq!(ing.bytes, 16 * 8 * 1024);
        assert_eq!(
            aggs[IoClass::Checkpoint.index()].completed as usize,
            2 * c.ckpt_writes
        );
        assert_eq!(aggs[IoClass::Drain.index()].completed, 0);
        assert_eq!(r.events, trace.events.len() as u64);
        // Origin tags attribute the traffic.
        assert!(trace
            .events
            .iter()
            .filter(|e| e.class == IoClass::Ingest)
            .all(|e| e.origin == "sharded-reader"));
        assert!(trace
            .events
            .iter()
            .filter(|e| e.class == IoClass::Checkpoint)
            .all(|e| e.origin == "saver"));
    }

    #[test]
    fn miniapp_trace_records_all_three_classes() {
        let (c, out) = cfg("mini", "miniapp");
        let r = run(&c, QosConfig::default(), &out).unwrap();
        assert!(r.drains > 0);
        let trace = Trace::load(&out).unwrap();
        assert_eq!(trace.manifest.devices.len(), 2, "drain device recorded");
        let aggs = trace.recorded_aggregates();
        assert!(aggs[IoClass::Ingest.index()].completed > 0);
        assert!(aggs[IoClass::Checkpoint.index()].completed > 0);
        // Each drain copy = read half + write half, both Drain-class.
        assert_eq!(aggs[IoClass::Drain.index()].completed, 2 * r.drains);
        assert!(trace
            .events
            .iter()
            .filter(|e| e.class == IoClass::Drain)
            .all(|e| e.origin == "bb-drain"));
        // And the whole trace closed-loop replays cleanly against its
        // recorded two-device setup.
        let outcome = replay(&trace, &ReplayConfig::default()).unwrap();
        assert_eq!(outcome.errors, 0);
        assert_eq!(outcome.replayed.len(), trace.events.len());
    }

    #[test]
    fn unknown_workload_and_device_are_rejected() {
        let (mut c, out) = cfg("bad", "banana");
        assert!(run(&c, QosConfig::default(), &out).is_err());
        c.workload = "microbench".into();
        c.device = "floppy".into();
        assert!(run(&c, QosConfig::default(), &out).is_err());
    }
}
