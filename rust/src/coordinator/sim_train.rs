//! The modelled training run (`dlio train --compute model`): the
//! paper's mini-app structure with the XLA step replaced by the
//! calibrated [`AccelModel`] (DESIGN.md §16).
//!
//! Everything is artifact-free: an engine-backed sharded reader pulls
//! a synthetic corpus (flat device or `hier:<preset>`), batches feed
//! the [`run_loop`] through a bounded [`SimPrefetch`] queue, the
//! accelerator occupies the shared [`Clock`] for each step's modelled
//! duration, and checkpoints save synthetic state through the real
//! `Saver`/`BurstBuffer` machinery.  Under the virtual clock the whole
//! run is discrete-event and bit-deterministic — the substrate the
//! overlap sweep and the §15 bench gate measure.
//!
//! [`SimPrefetch`]: crate::pipeline::SimPrefetch
//! [`Clock`]: crate::storage::Clock

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::checkpoint::{BurstBuffer, Saver};
use crate::compute::{
    run_loop, AccelModel, AccelTier, ComputeProfile, LoopConfig,
    StepRecord, StepSummary,
};
use crate::config::{CheckpointTarget, Testbed, DEFAULT_SHARD_WINDOW};
use crate::data::manifest::Sample;
use crate::model::ModelState;
use crate::pipeline::{
    sharded_reader, sharded_reader_hier, Dataset, ShardedReader,
};
use crate::runtime::meta::{ParamSpec, ProfileMeta};
use crate::storage::{ClockSpec, QosConfig, SimPath, StorageSim};
use crate::trace::{append_steps, TraceManifest, TraceRecorder, TRACE_VERSION};

use super::fixtures::{build_hierarchy, StorageTarget};

/// Shape of one modelled training run.
#[derive(Debug, Clone)]
pub struct SimTrainConfig {
    /// Storage target: a device name or `hier:<preset>` (corpus homed
    /// on the preset's bottom tier, reads routed through it).
    pub device: String,
    /// Reader shards / per-shard in-flight window.
    pub shards: usize,
    pub window: usize,
    /// Images per batch.
    pub batch: usize,
    /// Training steps (the corpus is sized to exactly one epoch).
    pub steps: usize,
    /// Prefetch queue depth between pipeline and accelerator
    /// (0 = synchronous).
    pub prefetch: usize,
    /// Bytes per corpus file.
    pub file_bytes: usize,
    /// Compute profile name ([`crate::compute::PROFILE_NAMES`]).
    pub profile: String,
    /// Accelerator tier name ([`crate::compute::TIER_NAMES`]).
    pub tier: String,
    /// Checkpoint target; `Direct` saves route through the hierarchy
    /// when the storage target is `hier:<preset>`.
    pub ckpt: CheckpointTarget,
    /// Checkpoint every N steps (0 = never).
    pub ckpt_interval: usize,
    /// Synthetic checkpoint size, f32 elements.
    pub ckpt_params: usize,
    pub max_to_keep: usize,
    /// Simulation speed-up, applied to storage and compute alike so
    /// the compute-vs-I/O ratio survives scaling.
    pub time_scale: f64,
    /// Working directory root (the run gets a subdirectory).
    pub workdir: String,
    /// Time source: virtual (default) = exact discrete-event run.
    pub clock: ClockSpec,
    /// When set, record a schema-v4 trace here: request events plus
    /// the per-step records.
    pub trace_out: Option<PathBuf>,
}

impl SimTrainConfig {
    pub fn standard(workdir: String, time_scale: f64) -> SimTrainConfig {
        SimTrainConfig {
            device: "ssd".into(),
            shards: 2,
            window: DEFAULT_SHARD_WINDOW,
            batch: 16,
            steps: 20,
            prefetch: 2,
            file_bytes: 64 * 1024,
            profile: "alexnet".into(),
            tier: "k80".into(),
            ckpt: CheckpointTarget::None,
            ckpt_interval: 0,
            ckpt_params: 64 * 1024,
            max_to_keep: 3,
            time_scale,
            workdir,
            clock: ClockSpec::Virtual,
            trace_out: None,
        }
    }
}

/// What a modelled run produced.
pub struct SimTrainResult {
    /// The run's sim, for `--engine-stats`-style reporting.
    pub sim: Arc<StorageSim>,
    /// Resolved data device (the preset's bottom tier for hier
    /// targets).
    pub data_device: String,
    pub records: Vec<StepRecord>,
    pub summary: StepSummary,
    /// The accelerator's post-warm-up step duration — the `C` term of
    /// the overlap regime, exact by construction.
    pub modelled_step_secs: f64,
    /// Request events written to `trace_out` (None = not recording).
    pub trace_events: Option<u64>,
}

/// Fold loaded samples into per-batch image counts — the training
/// loop consumes batches, not files.  A partial trailing batch is
/// dropped (`drop_remainder`, like the mini-app's shape-specialized
/// HLO).
struct CountBatches {
    inner: ShardedReader,
    batch: usize,
}

impl Dataset for CountBatches {
    type Item = u64;

    fn next(&mut self) -> Option<Result<u64>> {
        for _ in 0..self.batch {
            match self.inner.next() {
                Some(Ok(_)) => {}
                Some(Err(e)) => return Some(Err(e)),
                None => return None,
            }
        }
        Some(Ok(self.batch as u64))
    }
}

/// Synthetic checkpoint payload: one flat tensor of `params` f32
/// elements — the artifact-free shape the tier sweep saves.
fn ckpt_profile(params: usize) -> ProfileMeta {
    let params = params.max(16);
    ProfileMeta {
        name: "sim-train".into(),
        input_size: 8,
        num_classes: 4,
        num_params: params,
        params: vec![ParamSpec {
            name: "fc1/kernel".into(),
            shape: vec![params],
        }],
    }
}

enum Ckpt {
    None,
    Direct(Saver),
    Bb(BurstBuffer),
}

/// Run one modelled training cell.
pub fn run(cfg: &SimTrainConfig) -> Result<SimTrainResult> {
    if !(cfg.time_scale > 0.0) {
        bail!("time scale must be positive, got {}", cfg.time_scale);
    }
    let target = StorageTarget::parse(&cfg.device);
    let dir = Path::new(&cfg.workdir).join("sim-train");
    let _ = std::fs::remove_dir_all(&dir);
    let clock = cfg.clock.build();
    let qos = QosConfig::default();
    // The full paper testbed, so hier presets and checkpoint targets
    // resolve whatever devices they name.
    let testbed = Testbed::paper(cfg.time_scale);
    let sim = Arc::new(StorageSim::cold_with_qos_clock(
        dir,
        testbed.devices.clone(),
        qos.clone(),
        clock.clone(),
    )?);

    let (hier, data_device) = match &target {
        StorageTarget::Flat(dev) => {
            if !testbed.devices.iter().any(|m| m.name == *dev) {
                bail!(
                    "unknown device {dev:?} (valid: {})",
                    testbed
                        .devices
                        .iter()
                        .map(|m| m.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                );
            }
            (None, dev.clone())
        }
        StorageTarget::Hier(preset) => {
            let (h, bottom) = build_hierarchy(&sim, preset)?;
            (Some(h), bottom)
        }
    };

    // Validate the model knobs before paying for the corpus.
    let batch = cfg.batch.max(1);
    let steps = cfg.steps.max(1);
    let accel = AccelModel::new(
        ComputeProfile::by_name(&cfg.profile)?,
        AccelTier::by_name(&cfg.tier)?,
        batch,
        cfg.time_scale,
        clock.clone(),
    )?;

    // Fixture: exactly one epoch of corpus, excluded from the
    // measured stats and any trace.
    let samples: Vec<Sample> = (0..steps * batch)
        .map(|i| -> Result<Sample> {
            let p = SimPath::new(&data_device, format!("corpus/f{i}.bin"));
            sim.write(&p, &vec![(i % 251) as u8; cfg.file_bytes])?;
            Ok(Sample { path: p, label: i as u32 })
        })
        .collect::<Result<_>>()?;
    sim.drop_caches();
    sim.engine().reset_stats();

    // Optional request-level recorder: the trace carries exactly the
    // measured phase, with the step records appended after finish().
    let recorder = match &cfg.trace_out {
        None => None,
        Some(out) => {
            let manifest = TraceManifest {
                version: TRACE_VERSION,
                workload: format!(
                    "sim-train device={} profile={} tier={} batch={} \
                     steps={} prefetch={} shards={} window={} \
                     ckpt={} ckpt_interval={}",
                    cfg.device,
                    cfg.profile,
                    cfg.tier,
                    batch,
                    steps,
                    cfg.prefetch,
                    cfg.shards,
                    cfg.window,
                    cfg.ckpt.label(),
                    cfg.ckpt_interval,
                ),
                qos_mode: qos.mode_name().to_string(),
                qos: Some(qos.clone()),
                time_scale: cfg.time_scale,
                devices: testbed.devices.clone(),
            };
            let rec = TraceRecorder::create(out, &manifest)?;
            sim.engine().set_observer(rec.observer());
            Some(rec)
        }
    };

    let reader = match &hier {
        Some(h) => sharded_reader_hier(
            samples,
            Arc::clone(h),
            cfg.shards.max(1),
            cfg.window.max(1),
        ),
        None => sharded_reader(
            samples,
            Arc::clone(&sim),
            cfg.shards.max(1),
            cfg.window.max(1),
        ),
    };
    let batches = CountBatches { inner: reader, batch };

    // Checkpoint sink over synthetic state.  Hier-target runs route
    // Direct saves through the hierarchy, so the placement policy
    // picks the tier exactly like the routed ckpt-study path.
    let (mut sink, state) = match &cfg.ckpt {
        CheckpointTarget::None => (Ckpt::None, None),
        other => {
            let profile = ckpt_profile(cfg.ckpt_params);
            let state = ModelState::init(&profile, 7);
            let sink = match other {
                CheckpointTarget::None => unreachable!(),
                CheckpointTarget::Direct(dev) => {
                    let mut saver = Saver::new(
                        Arc::clone(&sim),
                        profile,
                        dev,
                        "ckpt/model",
                        cfg.max_to_keep,
                    );
                    if let Some(h) = &hier {
                        saver.set_route(Arc::clone(h));
                    }
                    saver.sync_on_save = false;
                    Ckpt::Direct(saver)
                }
                CheckpointTarget::BurstBuffer { fast, slow } => {
                    Ckpt::Bb(BurstBuffer::new(
                        Arc::clone(&sim),
                        profile,
                        fast,
                        slow,
                        "ckpt/model",
                        cfg.max_to_keep,
                    )?)
                }
            };
            (sink, Some(state))
        }
    };

    let loop_cfg = LoopConfig {
        prefetch: cfg.prefetch,
        max_steps: steps,
        ckpt_interval: match cfg.ckpt {
            CheckpointTarget::None => 0,
            _ => cfg.ckpt_interval,
        },
    };
    let mut on_ckpt = |step: u64| -> Result<()> {
        let state = state.as_ref().expect("ckpt sink without state");
        match &mut sink {
            Ckpt::None => Ok(()),
            Ckpt::Direct(s) => s.save(state, step).map(|_| ()),
            Ckpt::Bb(b) => b.save(state, step).map(|_| ()),
        }
    };
    let outcome = run_loop(batches, &accel, &loop_cfg, Some(&mut on_ckpt))
        .context("sim-train loop failed")?;

    // Like the mini-app: training time is already captured; the
    // burst-buffer drain completes off the step clock.
    if let Ckpt::Bb(bb) = &sink {
        bb.wait_drained();
    }

    let trace_events = match recorder {
        None => None,
        Some(rec) => {
            sim.engine().clear_observer();
            let path = rec.path().clone();
            let events = rec.finish()?;
            append_steps(path, &outcome.records)?;
            Some(events)
        }
    };

    Ok(SimTrainResult {
        sim,
        data_device,
        records: outcome.records,
        summary: outcome.summary,
        modelled_step_secs: accel.steady_step_secs(),
        trace_events,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::Trace;

    fn tiny_cfg(tag: &str) -> SimTrainConfig {
        let dir = std::env::temp_dir().join(format!(
            "dlio-sim-train-test-{tag}-{}",
            std::process::id()
        ));
        let mut c = SimTrainConfig::standard(
            dir.to_string_lossy().into_owned(),
            1000.0,
        );
        c.profile = "micro".into();
        c.batch = 4;
        c.steps = 6;
        c.file_bytes = 4 * 1024;
        c
    }

    #[test]
    fn two_virtual_runs_are_bit_identical() {
        let cfg = tiny_cfg("det");
        let a = run(&cfg).unwrap();
        let b = run(&cfg).unwrap();
        assert_eq!(a.summary.steps, 6);
        assert_eq!(a.summary.images, 24);
        // Bit-identical f64s, not tolerances: the virtual-clock
        // determinism contract, end-to-end through the engine-backed
        // reader, the prefetch queue, and the accelerator.
        assert_eq!(a.records, b.records);
        assert_eq!(a.summary, b.summary);
        assert_eq!(a.modelled_step_secs, b.modelled_step_secs);
    }

    #[test]
    fn hier_target_routes_data_and_checkpoints() {
        let mut cfg = tiny_cfg("hier");
        cfg.device = "hier:blackdog-bb".into();
        cfg.ckpt = CheckpointTarget::Direct("ssd".into());
        cfg.ckpt_interval = 2;
        cfg.ckpt_params = 1024;
        let r = run(&cfg).unwrap();
        assert_eq!(r.data_device, "hdd", "bb preset bottoms at hdd");
        assert_eq!(r.summary.steps, 6);
        // Saves fired on steps 2, 4, 6 and stalled the step thread.
        assert!(r.summary.ckpt_stall_secs > 0.0);
        for rec in &r.records {
            if (rec.step + 1) % 2 == 0 {
                assert!(
                    rec.ckpt_stall_secs > 0.0,
                    "step {} missing its save stall",
                    rec.step
                );
            } else {
                assert_eq!(rec.ckpt_stall_secs, 0.0, "step {}", rec.step);
            }
        }
    }

    #[test]
    fn trace_out_writes_a_v4_trace_with_steps_and_events() {
        let mut cfg = tiny_cfg("trace");
        let out = Path::new(&cfg.workdir).join("train-trace.jsonl");
        cfg.trace_out = Some(out.clone());
        let r = run(&cfg).unwrap();
        let events = r.trace_events.unwrap();
        assert!(events >= 24, "expected >= one read per image, got {events}");
        let trace = Trace::load(&out).unwrap();
        assert_eq!(trace.manifest.version, TRACE_VERSION);
        assert!(trace.manifest.workload.contains("sim-train"));
        assert_eq!(trace.events.len() as u64, events);
        assert_eq!(trace.steps.len(), r.records.len());
        assert_eq!(trace.steps, r.records);
    }

    #[test]
    fn unknown_knobs_are_rejected_before_running() {
        let mut cfg = tiny_cfg("baddev");
        cfg.device = "floppy".into();
        assert!(run(&cfg).is_err());
        let mut cfg = tiny_cfg("badprof");
        cfg.profile = "resnet".into();
        assert!(run(&cfg).is_err());
        let mut cfg = tiny_cfg("badtier");
        cfg.tier = "tpu".into();
        assert!(run(&cfg).is_err());
    }
}
