//! `dlio overlap-sweep` — the prefetcher-overlap characterization
//! driver (DESIGN.md §16): the paper's headline result as a matrix.
//!
//! The paper shows that with enough prefetch depth the training step
//! time converges to `max(compute, input)` — the input pipeline
//! completely overlaps the accelerator and the *effective* cost of
//! I/O drops to ~0 — while a synchronous loop pays the two costs
//! additively.  This sweep runs that experiment as (storage target ×
//! reader shards × prefetch depth) cells of [`sim_train`] under the
//! virtual clock, and reports each cell next to its two analytic
//! anchors:
//!
//! * `compute_ms_per_step` — the accelerator model's exact
//!   post-warm-up step cost (`C`).
//! * `input_ms_per_step` — the pure input-pipeline cost per batch
//!   (`I`), measured by a drain cell (compute profile `none`,
//!   prefetch 0) over the same (target, shards) fixture.
//!
//! A cell in the overlap regime shows `step_ms ≈ max(C, I)` and
//! `stall_frac → 0`; the `prefetch = 0` column stays additive.  The
//! §15 bench gate asserts exactly that on a pinned cell.

use std::path::Path;

use anyhow::Result;

use crate::compute::{AccelTier, ComputeProfile, StepSummary};
use crate::config::DEFAULT_SHARD_WINDOW;
use crate::storage::ClockSpec;
use crate::util::json::{obj, to_string, Json};

use super::sim_train::{self, SimTrainConfig, SimTrainResult};

/// Sweep matrix + cell shape.
#[derive(Debug, Clone)]
pub struct OverlapSweepConfig {
    /// Storage targets: device names and/or `hier:<preset>`.
    pub targets: Vec<String>,
    /// Reader shard counts.
    pub shards: Vec<usize>,
    /// Prefetch depths (0 = synchronous).
    pub prefetch: Vec<usize>,
    /// Per-shard in-flight read window.
    pub window: usize,
    /// Images per batch.
    pub batch: usize,
    /// Steps per cell (corpus = exactly one epoch).
    pub steps: usize,
    /// Bytes per corpus file.
    pub file_bytes: usize,
    /// Compute profile / accelerator tier for the measured cells
    /// (drain cells always run profile `none`).
    pub profile: String,
    pub tier: String,
    /// Simulation speed-up.
    pub time_scale: f64,
    /// Working directory root (each cell gets a subdirectory).
    pub workdir: String,
    /// Time source per cell; virtual (the default) makes every cell
    /// exact and the matrix fast.
    pub clock: ClockSpec,
}

impl OverlapSweepConfig {
    /// Full default matrix: 3 targets x 2 shard counts x 4 depths.
    pub fn standard(workdir: String, time_scale: f64) -> OverlapSweepConfig {
        OverlapSweepConfig {
            targets: vec![
                "ssd".into(),
                "hdd".into(),
                "hier:blackdog-bb".into(),
            ],
            shards: vec![1, 4],
            prefetch: vec![0, 1, 2, 4],
            window: DEFAULT_SHARD_WINDOW,
            batch: 16,
            steps: 24,
            file_bytes: 64 * 1024,
            profile: "alexnet".into(),
            tier: "k80".into(),
            time_scale,
            workdir,
            clock: ClockSpec::Virtual,
        }
    }

    /// Tiny matrix for CI: 1 target x 1 shard count x 3 depths.
    pub fn smoke(workdir: String, time_scale: f64) -> OverlapSweepConfig {
        OverlapSweepConfig {
            targets: vec!["ssd".into()],
            shards: vec![2],
            prefetch: vec![0, 1, 2],
            batch: 8,
            steps: 10,
            file_bytes: 16 * 1024,
            profile: "micro".into(),
            ..OverlapSweepConfig::standard(workdir, time_scale)
        }
    }
}

/// One (target, shards, prefetch) cell of the sweep.
#[derive(Debug, Clone)]
pub struct OverlapSweepRow {
    pub target: String,
    pub shards: usize,
    pub prefetch: usize,
    /// Resolved data device (hier targets bottom out on the preset's
    /// slow tier).
    pub device: String,
    pub steps: u64,
    pub images: u64,
    /// The accelerator model's exact post-warm-up step cost, `C`.
    pub compute_ms_per_step: f64,
    /// Pure input cost per batch from the drain cell, `I`.
    pub input_ms_per_step: f64,
    /// Measured post-warm-up mean step duration.
    pub step_ms: f64,
    pub stall_frac: f64,
    pub overlap_frac: f64,
    /// Stall time amortized per step — the effective I/O cost after
    /// overlap.
    pub eff_io_ms_per_step: f64,
    pub images_per_sec: f64,
    pub elapsed_secs: f64,
}

/// CSV column order — one place so header and rows can't drift.
const CSV_COLUMNS: [&str; 14] = [
    "target",
    "shards",
    "prefetch",
    "device",
    "steps",
    "images",
    "compute_ms_per_step",
    "input_ms_per_step",
    "step_ms",
    "stall_frac",
    "overlap_frac",
    "eff_io_ms_per_step",
    "images_per_sec",
    "elapsed_secs",
];

impl OverlapSweepRow {
    fn csv_row(&self) -> String {
        [
            self.target.clone(),
            self.shards.to_string(),
            self.prefetch.to_string(),
            self.device.clone(),
            self.steps.to_string(),
            self.images.to_string(),
            format!("{:.4}", self.compute_ms_per_step),
            format!("{:.4}", self.input_ms_per_step),
            format!("{:.4}", self.step_ms),
            format!("{:.4}", self.stall_frac),
            format!("{:.4}", self.overlap_frac),
            format!("{:.4}", self.eff_io_ms_per_step),
            format!("{:.1}", self.images_per_sec),
            format!("{:.4}", self.elapsed_secs),
        ]
        .join(",")
    }

    fn json_value(&self) -> Json {
        obj(vec![
            ("target", Json::Str(self.target.clone())),
            ("shards", Json::Num(self.shards as f64)),
            ("prefetch", Json::Num(self.prefetch as f64)),
            ("device", Json::Str(self.device.clone())),
            ("steps", Json::Num(self.steps as f64)),
            ("images", Json::Num(self.images as f64)),
            ("compute_ms_per_step", Json::Num(self.compute_ms_per_step)),
            ("input_ms_per_step", Json::Num(self.input_ms_per_step)),
            ("step_ms", Json::Num(self.step_ms)),
            ("stall_frac", Json::Num(self.stall_frac)),
            ("overlap_frac", Json::Num(self.overlap_frac)),
            ("eff_io_ms_per_step", Json::Num(self.eff_io_ms_per_step)),
            ("images_per_sec", Json::Num(self.images_per_sec)),
            ("elapsed_secs", Json::Num(self.elapsed_secs)),
        ])
    }
}

/// Render rows as CSV (header + one line per row).
pub fn to_csv(rows: &[OverlapSweepRow]) -> String {
    let mut out = CSV_COLUMNS.join(",");
    out.push('\n');
    for r in rows {
        out.push_str(&r.csv_row());
        out.push('\n');
    }
    out
}

/// Render rows as a JSON array (one object per row).
pub fn to_json(rows: &[OverlapSweepRow]) -> String {
    to_string(&Json::Arr(rows.iter().map(|r| r.json_value()).collect()))
}

/// Run the full matrix; rows come back in (target, shards, prefetch)
/// iteration order.
pub fn run(cfg: &OverlapSweepConfig) -> Result<Vec<OverlapSweepRow>> {
    // Resolve the model knobs once, before any cell pays for fixtures.
    let profile = ComputeProfile::by_name(&cfg.profile)?;
    AccelTier::by_name(&cfg.tier)?;
    let warm = profile.warmup_steps as usize;
    let mut rows = Vec::new();
    for target in &cfg.targets {
        for &shards in &cfg.shards {
            // Drain cell: the pure input-pipeline cost per batch over
            // exactly this (target, shards) fixture.  `none` has no
            // warm-up, so the steady mean spans every step.
            let drain = run_cell(cfg, target, shards, 0, "none")?;
            let input_secs =
                StepSummary::steady_mean_step_secs(&drain.records, 0);
            for &prefetch in &cfg.prefetch {
                let r = run_cell(cfg, target, shards, prefetch, &cfg.profile)?;
                let steady =
                    StepSummary::steady_mean_step_secs(&r.records, warm);
                rows.push(OverlapSweepRow {
                    target: target.clone(),
                    shards,
                    prefetch,
                    device: r.data_device.clone(),
                    steps: r.summary.steps,
                    images: r.summary.images,
                    compute_ms_per_step: r.modelled_step_secs * 1e3,
                    input_ms_per_step: input_secs * 1e3,
                    step_ms: steady * 1e3,
                    stall_frac: r.summary.stall_frac,
                    overlap_frac: r.summary.overlap_frac,
                    eff_io_ms_per_step: r.summary.effective_io_secs_per_step
                        * 1e3,
                    images_per_sec: r.summary.images_per_sec,
                    elapsed_secs: r.summary.total_secs,
                });
            }
        }
    }
    Ok(rows)
}

fn run_cell(
    cfg: &OverlapSweepConfig,
    target: &str,
    shards: usize,
    prefetch: usize,
    profile: &str,
) -> Result<SimTrainResult> {
    let tag = target.replace(':', "-");
    let dir = Path::new(&cfg.workdir)
        .join(format!("overlap-{tag}-s{shards}-p{prefetch}-{profile}"));
    let mut c = SimTrainConfig::standard(
        dir.to_string_lossy().into_owned(),
        cfg.time_scale,
    );
    c.device = target.to_string();
    c.shards = shards;
    c.window = cfg.window;
    c.batch = cfg.batch;
    c.steps = cfg.steps;
    c.prefetch = prefetch;
    c.file_bytes = cfg.file_bytes;
    c.profile = profile.to_string();
    c.tier = cfg.tier.clone();
    c.clock = cfg.clock.clone();
    sim_train::run(&c)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn workdir(tag: &str) -> String {
        std::env::temp_dir()
            .join(format!(
                "dlio-overlap-sweep-test-{tag}-{}",
                std::process::id()
            ))
            .to_string_lossy()
            .into_owned()
    }

    #[test]
    fn smoke_matrix_emits_one_row_per_cell() {
        let cfg = OverlapSweepConfig::smoke(workdir("rows"), 1000.0);
        let rows = run(&cfg).unwrap();
        assert_eq!(rows.len(), 3); // 1 target x 1 shard count x 3 depths
        for r in &rows {
            assert_eq!(r.target, "ssd");
            assert_eq!(r.device, "ssd");
            assert_eq!(r.steps, 10);
            assert_eq!(r.images, 80);
            assert!(r.compute_ms_per_step > 0.0);
            assert!(r.input_ms_per_step > 0.0);
            assert!(r.step_ms > 0.0);
            assert!((0.0..=1.0).contains(&r.stall_frac), "{}", r.stall_frac);
            assert!(
                (r.stall_frac + r.overlap_frac - 1.0).abs() < 1e-9,
                "fractions must partition the loop"
            );
        }
        // CSV: header + one line per row, constant column count.
        let csv = to_csv(&rows);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 4);
        let ncols = lines[0].split(',').count();
        assert_eq!(ncols, CSV_COLUMNS.len());
        for l in &lines {
            assert_eq!(l.split(',').count(), ncols, "ragged CSV: {l}");
        }
        // JSON round-trips through the in-repo parser.
        let parsed = Json::parse(&to_json(&rows)).unwrap();
        match parsed {
            Json::Arr(out) => {
                assert_eq!(out.len(), 3);
                for r in out {
                    assert!(r.get("target").and_then(Json::as_str).is_some());
                    assert!(r.get("step_ms").and_then(Json::as_f64).is_some());
                }
            }
            other => panic!("expected a JSON array, got {other:?}"),
        }
    }

    #[test]
    fn prefetch_overlaps_on_a_compute_bound_cell() {
        // Pinned compute-bound cell: micro @ batch 8 gives C = 0.9 ms
        // while 8 x 16 KiB off the ssd costs well under that, and a
        // 1-shard / 1-wide window means the synchronous column can
        // only hide one read per step — the additive regime.
        let mut cfg = OverlapSweepConfig::smoke(workdir("overlap"), 1.0);
        cfg.shards = vec![1];
        cfg.window = 1;
        cfg.prefetch = vec![0, 4];
        cfg.steps = 12;
        let rows = run(&cfg).unwrap();
        assert_eq!(rows.len(), 2);
        let sync = &rows[0];
        let over = &rows[1];
        assert_eq!(sync.prefetch, 0);
        assert_eq!(over.prefetch, 4);
        let c = sync.compute_ms_per_step;
        let i = sync.input_ms_per_step;
        assert!(c > i, "cell must be compute-bound: C {c} vs I {i}");
        // Deep prefetch: step converges to max(C, I) = C.
        assert!(
            over.step_ms <= 1.10 * c.max(i),
            "overlap step {} > 1.1 x max(C,I) {}",
            over.step_ms,
            c.max(i)
        );
        // Synchronous pays the input cost the overlap column hides.
        assert!(
            sync.step_ms > over.step_ms,
            "sync {} must exceed overlapped {}",
            sync.step_ms,
            over.step_ms
        );
        assert!(
            over.eff_io_ms_per_step < sync.eff_io_ms_per_step,
            "prefetch must shrink the effective I/O cost"
        );
    }

    #[test]
    fn unknown_profile_fails_before_any_cell() {
        let mut cfg = OverlapSweepConfig::smoke(workdir("badprof"), 1000.0);
        cfg.profile = "resnet".into();
        assert!(run(&cfg).is_err());
        let mut cfg = OverlapSweepConfig::smoke(workdir("badtarget"), 1000.0);
        cfg.targets = vec!["floppy".into()];
        assert!(run(&cfg).is_err());
    }
}
