//! The STREAM-like TensorFlow-I/O micro-benchmark (§III-A).
//!
//! Pipeline: manifest -> shuffle -> parallel map (read [+ decode +
//! fused resize]) -> ignore_errors -> batch -> iterator, consumed as
//! fast as possible with *no* compute phase; bandwidth = images and
//! bytes through the iterator per second.  Regenerates Figs. 4 & 5.

use std::sync::Arc;

use anyhow::Result;

use crate::config::MicrobenchConfig;
use crate::data::manifest::{Manifest, Sample};
use crate::metrics::Timer;
use crate::pipeline::{
    collect, from_manifest, sharded_reader, sharded_reader_hier, Dataset,
    DatasetExt,
};
use crate::runtime::Runtime;
use crate::storage::{StorageHierarchy, StorageSim};
use crate::util::Rng;

use super::workload::{preprocess_fn, preprocess_loaded_fn, read_only_fn};

/// Micro-benchmark outcome.
#[derive(Debug, Clone)]
pub struct MicrobenchResult {
    pub images: u64,
    pub bytes: u64,
    pub elapsed_secs: f64,
    pub dropped: u64,
}

impl MicrobenchResult {
    pub fn images_per_sec(&self) -> f64 {
        self.images as f64 / self.elapsed_secs
    }

    pub fn mb_per_sec(&self) -> f64 {
        self.bytes as f64 / 1e6 / self.elapsed_secs
    }
}

/// Run the micro-benchmark over `manifest` on `sim`.
pub fn run(
    sim: Arc<StorageSim>,
    rt: &Runtime,
    manifest: &Manifest,
    cfg: &MicrobenchConfig,
    seed: u64,
) -> Result<MicrobenchResult> {
    let total_images = cfg.batch * cfg.iterations;
    let m = manifest.truncated(total_images.min(manifest.len()));
    // Shuffle buffer = full dataset, as the micro-benchmark shuffles
    // the whole path list (§III-A).
    let shuffle_buf = m.len().max(1);

    let mut images = 0u64;
    let mut bytes = 0u64;
    let mut dropped = 0u64;
    let timer;

    // Shuffled sample list for the engine-backed sharded source (the
    // shuffle buffer covers the whole list, so materializing it first
    // is semantics-preserving).
    let shuffled = |seed: u64| -> Result<Vec<Sample>> {
        collect(from_manifest(&m).shuffle(shuffle_buf, Rng::new(seed)))
    };
    let shards = cfg.shards.max(1);
    // `--shards N` alone implies the engine-backed source with the
    // default per-shard window (never the blocking path silently).
    let readahead = cfg.effective_readahead();

    if cfg.preprocess && readahead > 0 {
        // Engine sharded readahead: file reads queue on the device
        // engine across `shards` reader shards ahead of the decode
        // workers (no thread parked per read).
        let f = preprocess_loaded_fn(rt, m.src_size as usize, cfg.out_size)?;
        let src = sharded_reader(
            shuffled(seed)?,
            Arc::clone(&sim),
            shards,
            readahead,
        );
        // The decode window mirrors the total read window so loaded
        // bytes keep flowing while the consumer drains a batch.
        let ds = src
            .parallel_map_ahead(cfg.threads, readahead * shards, f)
            .ignore_errors();
        let counter = ds.dropped_counter();
        let mut ds = ds.batch(cfg.batch, false).take(cfg.iterations);
        timer = Timer::start();
        while let Some(batch) = ds.next() {
            let batch = batch?;
            images += batch.len() as u64;
            bytes += batch.iter().map(|p| p.bytes_read).sum::<u64>();
        }
        dropped += counter.load(std::sync::atomic::Ordering::Relaxed);
    } else if cfg.preprocess {
        let f = preprocess_fn(
            Arc::clone(&sim),
            rt,
            m.src_size as usize,
            cfg.out_size,
        )?;
        let ds = from_manifest(&m)
            .shuffle(shuffle_buf, Rng::new(seed))
            .parallel_map(cfg.threads, f)
            .ignore_errors();
        let counter = ds.dropped_counter();
        let mut ds = ds.batch(cfg.batch, false).take(cfg.iterations);
        timer = Timer::start();
        while let Some(batch) = ds.next() {
            let batch = batch?;
            images += batch.len() as u64;
            bytes += batch.iter().map(|p| p.bytes_read).sum::<u64>();
        }
        dropped += counter.load(std::sync::atomic::Ordering::Relaxed);
    } else if readahead > 0 {
        let src = sharded_reader(
            shuffled(seed)?,
            Arc::clone(&sim),
            shards,
            readahead,
        );
        let ds = src.ignore_errors();
        let counter = ds.dropped_counter();
        let mut ds = ds.batch(cfg.batch, false).take(cfg.iterations);
        timer = Timer::start();
        while let Some(batch) = ds.next() {
            let batch = batch?;
            images += batch.len() as u64;
            bytes += batch.iter().map(|ls| ls.bytes.len() as u64).sum::<u64>();
        }
        dropped += counter.load(std::sync::atomic::Ordering::Relaxed);
    } else {
        let f = read_only_fn(Arc::clone(&sim));
        let ds = from_manifest(&m)
            .shuffle(shuffle_buf, Rng::new(seed))
            .parallel_map(cfg.threads, f)
            .ignore_errors();
        let counter = ds.dropped_counter();
        let mut ds = ds.batch(cfg.batch, false).take(cfg.iterations);
        timer = Timer::start();
        while let Some(batch) = ds.next() {
            let batch = batch?;
            images += batch.len() as u64;
            bytes += batch.iter().map(|r| r.bytes.len() as u64).sum::<u64>();
        }
        dropped += counter.load(std::sync::atomic::Ordering::Relaxed);
    }

    Ok(MicrobenchResult {
        images,
        bytes,
        elapsed_secs: timer.secs(),
        dropped,
    })
}

/// Run the micro-benchmark with reads routed through a storage
/// hierarchy (`--device hier:<preset>`) instead of straight at one
/// device.  Hierarchy routing only exists on the engine-backed
/// sharded source, so a readahead of at least 1 is always in force
/// here (the blocking per-thread read path has no tier seam).
pub fn run_hier(
    hier: Arc<StorageHierarchy>,
    rt: &Runtime,
    manifest: &Manifest,
    cfg: &MicrobenchConfig,
    seed: u64,
) -> Result<MicrobenchResult> {
    let total_images = cfg.batch * cfg.iterations;
    let m = manifest.truncated(total_images.min(manifest.len()));
    let shuffle_buf = m.len().max(1);
    let samples: Vec<Sample> =
        collect(from_manifest(&m).shuffle(shuffle_buf, Rng::new(seed)))?;
    let shards = cfg.shards.max(1);
    let readahead = cfg.effective_readahead().max(1);
    let src = sharded_reader_hier(samples, hier, shards, readahead);

    let mut images = 0u64;
    let mut bytes = 0u64;
    let dropped;
    let timer;
    if cfg.preprocess {
        let f =
            preprocess_loaded_fn(rt, m.src_size as usize, cfg.out_size)?;
        let ds = src
            .parallel_map_ahead(cfg.threads, readahead * shards, f)
            .ignore_errors();
        let counter = ds.dropped_counter();
        let mut ds = ds.batch(cfg.batch, false).take(cfg.iterations);
        timer = Timer::start();
        while let Some(batch) = ds.next() {
            let batch = batch?;
            images += batch.len() as u64;
            bytes += batch.iter().map(|p| p.bytes_read).sum::<u64>();
        }
        dropped = counter.load(std::sync::atomic::Ordering::Relaxed);
    } else {
        let ds = src.ignore_errors();
        let counter = ds.dropped_counter();
        let mut ds = ds.batch(cfg.batch, false).take(cfg.iterations);
        timer = Timer::start();
        while let Some(batch) = ds.next() {
            let batch = batch?;
            images += batch.len() as u64;
            bytes +=
                batch.iter().map(|ls| ls.bytes.len() as u64).sum::<u64>();
        }
        dropped = counter.load(std::sync::atomic::Ordering::Relaxed);
    }

    Ok(MicrobenchResult {
        images,
        bytes,
        elapsed_secs: timer.secs(),
        dropped,
    })
}
