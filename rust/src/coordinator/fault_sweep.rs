//! `dlio fault-sweep` — degraded-mode / fault-recovery study.
//!
//! The fault seam (DESIGN.md §15) makes device health injectable; this
//! driver characterizes what the engine's bounded-retry policy turns
//! those faults into.  One fixed closed-loop probe workload (`workers`
//! concurrent jobs of ingest reads plus periodic checkpoint writes)
//! runs against a single device while a [`FaultPlan`] window degrades
//! it, across the (fault kind × device profile) matrix.  Each cell
//! emits one CSV/JSON row with error/retry totals, the time-to-recover
//! (clock seconds from fault-clear to workload completion — 0 when the
//! workload drained, or died, inside the window) and the
//! goodput-retained fraction (bytes completed vs the same device's
//! no-fault baseline cell).
//!
//! The fault window is auto-sized per device from the baseline cell's
//! makespan (`fault_start_frac` / `fault_len_frac` are fractions of
//! it), so one matrix config spans profiles whose absolute service
//! times differ by orders of magnitude.
//!
//! Every cell also cross-checks the engine's error ledger against the
//! per-ticket outcomes: a retried request must count its final failure
//! exactly once, so a divergence fails the sweep instead of silently
//! skewing the rows.

use std::collections::HashMap;
use std::sync::{Arc, Barrier};

use anyhow::{anyhow, bail, Context, Result};

use crate::config::Testbed;
use crate::storage::engine::DEFAULT_CHUNK;
use crate::storage::{
    ClockSpec, Device, DeviceModel, FaultPlan, IoEngine, IoRequest,
    NullObserver, QosConfig,
};
use crate::util::json::{obj, to_string, Json};

/// Sweep matrix + per-cell workload shape.
#[derive(Debug, Clone)]
pub struct FaultSweepConfig {
    /// Device profiles, one matrix axis (`hdd|ssd|optane|lustre`).
    pub devices: Vec<String>,
    /// Fault kinds, the other axis (see
    /// [`FAULT_KINDS`](crate::storage::FAULT_KINDS)).
    pub kinds: Vec<String>,
    /// Concurrent closed-loop workers per cell.
    pub workers: usize,
    /// Ingest probe reads per worker.
    pub reads_per_worker: usize,
    /// Bytes per ingest read.
    pub read_bytes: u64,
    /// Checkpoint write every N reads (0 = no checkpoints).
    pub ckpt_every: usize,
    /// Bytes per checkpoint write.
    pub ckpt_bytes: u64,
    /// Fault window start, as a fraction of the baseline makespan.
    pub fault_start_frac: f64,
    /// Fault window length, as a fraction of the baseline makespan.
    pub fault_len_frac: f64,
    /// Device simulation speed-up.
    pub time_scale: f64,
    /// Time source per cell (virtual: the whole matrix is modelled,
    /// and identical runs are bit-deterministic).
    pub clock: ClockSpec,
}

impl FaultSweepConfig {
    /// Full matrix: every fault kind × {hdd, ssd} — 10 rows.
    pub fn standard(time_scale: f64) -> FaultSweepConfig {
        FaultSweepConfig {
            devices: vec!["hdd".into(), "ssd".into()],
            kinds: crate::storage::FAULT_KINDS
                .iter()
                .map(|k| k.to_string())
                .collect(),
            workers: 3,
            reads_per_worker: 24,
            read_bytes: 64 * 1024,
            ckpt_every: 8,
            ckpt_bytes: 512 * 1024,
            fault_start_frac: 0.1,
            fault_len_frac: 0.4,
            time_scale,
            clock: ClockSpec::Virtual,
        }
    }

    /// Tiny CI matrix: baseline + one soft and one hard fault on one
    /// device — 3 rows, seconds of wall time even on a slow host.
    pub fn smoke(time_scale: f64) -> FaultSweepConfig {
        FaultSweepConfig {
            devices: vec!["ssd".into()],
            kinds: vec!["none".into(), "slow".into(), "offline".into()],
            workers: 2,
            reads_per_worker: 10,
            read_bytes: 16 * 1024,
            ckpt_every: 5,
            ckpt_bytes: 128 * 1024,
            fault_start_frac: 0.1,
            fault_len_frac: 0.4,
            time_scale,
            clock: ClockSpec::Virtual,
        }
    }
}

/// One (fault kind × device) cell of the sweep.
#[derive(Debug, Clone)]
pub struct FaultSweepRow {
    pub kind: String,
    pub device: String,
    pub workers: usize,
    /// Requests offered (reads + checkpoint writes, all workers).
    pub submitted: u64,
    /// Requests whose ticket resolved Ok.
    pub completed: u64,
    /// Requests that finally failed (after the retry budget).
    pub errors: u64,
    /// Failed attempts the engine re-ran under the retry policy.
    pub retries: u64,
    /// Cell makespan, clock seconds.
    pub elapsed_secs: f64,
    /// Fault window start, clock seconds after the cell began (0 for
    /// the `none` baseline).
    pub fault_start_secs: f64,
    /// Fault window end — the scheduled recovery instant (0 for
    /// `none`).
    pub fault_clear_secs: f64,
    /// Clock seconds the workload kept running *after* the fault
    /// cleared — 0 when it drained (or died) inside the window.
    pub recover_secs: f64,
    /// Completed bytes over the cell makespan, MB/s.
    pub goodput_mbps: f64,
    /// Completed bytes as a fraction of the no-fault baseline cell's
    /// completed bytes (1.0 = the fault cost no work).
    pub goodput_retained: f64,
}

/// CSV column order — one place, so header and rows cannot drift.
const CSV_COLUMNS: [&str; 13] = [
    "kind",
    "device",
    "workers",
    "submitted",
    "completed",
    "errors",
    "retries",
    "elapsed_secs",
    "fault_start_secs",
    "fault_clear_secs",
    "recover_secs",
    "goodput_mbps",
    "goodput_retained",
];

impl FaultSweepRow {
    fn csv_row(&self) -> String {
        [
            self.kind.clone(),
            self.device.clone(),
            self.workers.to_string(),
            self.submitted.to_string(),
            self.completed.to_string(),
            self.errors.to_string(),
            self.retries.to_string(),
            format!("{:.6}", self.elapsed_secs),
            format!("{:.6}", self.fault_start_secs),
            format!("{:.6}", self.fault_clear_secs),
            format!("{:.6}", self.recover_secs),
            format!("{:.3}", self.goodput_mbps),
            format!("{:.4}", self.goodput_retained),
        ]
        .join(",")
    }

    fn json_value(&self) -> Json {
        obj(vec![
            ("kind", Json::Str(self.kind.clone())),
            ("device", Json::Str(self.device.clone())),
            ("workers", Json::Num(self.workers as f64)),
            ("submitted", Json::Num(self.submitted as f64)),
            ("completed", Json::Num(self.completed as f64)),
            ("errors", Json::Num(self.errors as f64)),
            ("retries", Json::Num(self.retries as f64)),
            ("elapsed_secs", Json::Num(self.elapsed_secs)),
            ("fault_start_secs", Json::Num(self.fault_start_secs)),
            ("fault_clear_secs", Json::Num(self.fault_clear_secs)),
            ("recover_secs", Json::Num(self.recover_secs)),
            ("goodput_mbps", Json::Num(self.goodput_mbps)),
            ("goodput_retained", Json::Num(self.goodput_retained)),
        ])
    }
}

/// Render rows as CSV (header + one line per cell).
pub fn to_csv(rows: &[FaultSweepRow]) -> String {
    let mut out = CSV_COLUMNS.join(",");
    out.push('\n');
    for r in rows {
        out.push_str(&r.csv_row());
        out.push('\n');
    }
    out
}

/// Render rows as a JSON array (one object per cell).
pub fn to_json(rows: &[FaultSweepRow]) -> String {
    to_string(&Json::Arr(rows.iter().map(|r| r.json_value()).collect()))
}

/// Minimum fault-window length, modelled seconds — several default
/// retry horizons (budget 2 × backoff 2 ms ≈ 6 ms of backoff per
/// request), so a mid-window request exhausts its budget while the
/// fault still holds.  Without the floor, a fraction-sized window on a
/// fast profile is shorter than one backoff cycle and every hard
/// fault turns into silent retry success, emptying the error column.
const MIN_FAULT_WINDOW_MODELLED_SECS: f64 = 0.03;

/// Device model for a profile name at the sweep's time scale.
fn device_model(cfg: &FaultSweepConfig, name: &str) -> Result<DeviceModel> {
    let models = Testbed::paper(cfg.time_scale).devices;
    match models.iter().find(|m| m.name == name) {
        Some(m) => Ok(m.clone()),
        None => {
            let names: Vec<&str> =
                models.iter().map(|m| m.name.as_str()).collect();
            Err(anyhow!(
                "unknown device {name:?} (valid: {})",
                names.join(", ")
            ))
        }
    }
}

/// Per-ticket outcome totals for one cell (all workers summed).
#[derive(Debug, Clone, Default)]
struct CellTotals {
    submitted: u64,
    ok: u64,
    errors: u64,
    bytes_ok: u64,
}

/// What one cell run measured, before baseline normalization.
#[derive(Debug, Clone)]
struct CellOutcome {
    totals: CellTotals,
    elapsed_secs: f64,
    retries: u64,
}

/// One worker's closed-loop job: reads with periodic checkpoint
/// writes, tolerating (and counting) per-request failures — degraded
/// mode means the job keeps going, it does not abort.
fn run_worker(
    engine: &IoEngine,
    device: &str,
    cfg: &FaultSweepConfig,
) -> CellTotals {
    let mut t = CellTotals::default();
    let mut issue = |req: IoRequest, bytes: u64, t: &mut CellTotals| {
        t.submitted += 1;
        match engine.submit(req).and_then(|tk| tk.wait()) {
            Ok(_) => {
                t.ok += 1;
                t.bytes_ok += bytes;
            }
            Err(_) => t.errors += 1,
        }
    };
    for i in 0..cfg.reads_per_worker {
        issue(
            IoRequest::ProbeRead {
                device: device.to_string(),
                bytes: cfg.read_bytes,
            },
            cfg.read_bytes,
            &mut t,
        );
        if cfg.ckpt_every > 0 && (i + 1) % cfg.ckpt_every == 0 {
            issue(
                IoRequest::ProbeWrite {
                    device: device.to_string(),
                    bytes: cfg.ckpt_bytes,
                },
                cfg.ckpt_bytes,
                &mut t,
            );
        }
    }
    t
}

/// Run one cell: fresh clock/device/engine, the fault plan armed over
/// `window` (clock seconds `(start, len)`; `None` = healthy baseline).
fn run_cell(
    cfg: &FaultSweepConfig,
    kind: &str,
    device_name: &str,
    window: Option<(f64, f64)>,
) -> Result<CellOutcome> {
    let clock = cfg.clock.build();
    let model = device_model(cfg, device_name)?;
    let dev = Arc::new(Device::with_clock(
        model,
        Arc::new(NullObserver),
        clock.clone(),
    ));
    let mut devices = HashMap::new();
    devices.insert(device_name.to_string(), Arc::clone(&dev));
    let engine = Arc::new(IoEngine::with_config(
        &devices,
        DEFAULT_CHUNK,
        QosConfig::default(),
    ));
    if kind != "none" {
        let (start, len) = window.unwrap_or((0.0, f64::INFINITY));
        // Round-trip through the same spec grammar `--inject` uses, so
        // the sweep exercises exactly the CLI's plan path.
        let plan =
            FaultPlan::parse(&format!("{kind}:{device_name}:{start}:{len}"))?;
        dev.set_health(plan.arm(device_name, &clock).map(Arc::new));
    }

    // Register-then-barrier: every worker registers with the clock
    // before any worker submits (the virtual-clock cell idiom).
    let barrier = Arc::new(Barrier::new(cfg.workers));
    let t0 = clock.now();
    let handles: Vec<_> = (0..cfg.workers)
        .map(|w| {
            let engine = Arc::clone(&engine);
            let clock = clock.clone();
            let barrier = Arc::clone(&barrier);
            let device = device_name.to_string();
            let cfg = cfg.clone();
            std::thread::Builder::new()
                .name(format!("fault-w{w}"))
                .spawn(move || {
                    let _reg = clock.enter();
                    barrier.wait();
                    run_worker(&engine, &device, &cfg)
                })
                .context("spawn fault-sweep worker")
        })
        .collect::<Result<_>>()?;
    let mut totals = CellTotals::default();
    for h in handles {
        let t = h
            .join()
            .map_err(|_| anyhow!("fault-sweep worker panicked"))?;
        totals.submitted += t.submitted;
        totals.ok += t.ok;
        totals.errors += t.errors;
        totals.bytes_ok += t.bytes_ok;
    }
    let elapsed_secs = (clock.now() - t0).max(1e-9);
    let stats = engine.stats();
    let retries: u64 = stats.iter().map(|s| s.retries).sum();
    let ledger_errors: u64 = stats.iter().map(|s| s.errors).sum();
    // Satellite invariant: a request retried N times then finally
    // failing must land on the engine ledger exactly once — if the
    // ledger and the per-ticket outcomes disagree, the rows are
    // meaningless, so fail loudly.
    if ledger_errors != totals.errors {
        bail!(
            "exactly-once error accounting broken on {device_name}/{kind}: \
             engine ledger {ledger_errors} vs ticket waits {}",
            totals.errors
        );
    }
    Ok(CellOutcome { totals, elapsed_secs, retries })
}

/// Run the full matrix; rows come back in (device, kind) iteration
/// order, one row per cell.  Every device runs an internal no-fault
/// baseline cell first (emitted only when `kinds` includes `none`),
/// which sizes the fault window and anchors `goodput_retained`.
pub fn run(cfg: &FaultSweepConfig) -> Result<Vec<FaultSweepRow>> {
    // Validate the whole matrix before running the first cell: a
    // typo'd kind must list the valid kinds instantly, not after
    // minutes of cells.
    for k in &cfg.kinds {
        FaultPlan::parse(k)?;
    }
    for d in &cfg.devices {
        device_model(cfg, d)?;
    }
    if cfg.workers == 0 || cfg.reads_per_worker == 0 {
        bail!("fault-sweep needs at least one worker and one read");
    }
    if cfg.fault_start_frac < 0.0 || cfg.fault_len_frac <= 0.0 {
        bail!(
            "fault window fractions must have start >= 0 and length > 0"
        );
    }
    let mut rows = Vec::new();
    for device in &cfg.devices {
        let base = run_cell(cfg, "none", device, None)?;
        let base_bytes = base.totals.bytes_ok.max(1) as f64;
        let start = cfg.fault_start_frac * base.elapsed_secs;
        let len = (cfg.fault_len_frac * base.elapsed_secs)
            .max(MIN_FAULT_WINDOW_MODELLED_SECS / cfg.time_scale);
        for kind in &cfg.kinds {
            let (out, window) = if kind == "none" {
                (base.clone(), None)
            } else {
                (run_cell(cfg, kind, device, Some((start, len)))?,
                 Some((start, len)))
            };
            let (fault_start_secs, fault_clear_secs, recover_secs) =
                match window {
                    None => (0.0, 0.0, 0.0),
                    Some((s, l)) => (
                        s,
                        s + l,
                        (out.elapsed_secs - (s + l)).max(0.0),
                    ),
                };
            rows.push(FaultSweepRow {
                kind: kind.clone(),
                device: device.clone(),
                workers: cfg.workers,
                submitted: out.totals.submitted,
                completed: out.totals.ok,
                errors: out.totals.errors,
                retries: out.retries,
                elapsed_secs: out.elapsed_secs,
                fault_start_secs,
                fault_clear_secs,
                recover_secs,
                goodput_mbps: out.totals.bytes_ok as f64
                    / out.elapsed_secs
                    / 1e6,
                goodput_retained: out.totals.bytes_ok as f64 / base_bytes,
            });
        }
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> FaultSweepConfig {
        let mut cfg = FaultSweepConfig::smoke(1000.0);
        cfg.reads_per_worker = 8;
        cfg.ckpt_every = 4;
        cfg
    }

    #[test]
    fn smoke_matrix_emits_one_row_per_kind_with_degradation_visible() {
        let cfg = tiny_cfg();
        let rows = run(&cfg).unwrap();
        assert_eq!(rows.len(), 3, "one row per (device, kind) cell");
        let row = |kind: &str| {
            rows.iter().find(|r| r.kind == kind).unwrap()
        };
        // Baseline: everything completes, nothing retried, the
        // retained fraction is exactly itself.
        let none = row("none");
        assert_eq!(none.errors, 0);
        assert_eq!(none.retries, 0);
        assert_eq!(none.completed, none.submitted);
        assert!((none.goodput_retained - 1.0).abs() < 1e-12);
        assert_eq!(none.recover_secs, 0.0);
        // Slow: every byte still lands (retained exactly 1) but the
        // makespan stretches past the baseline.
        let slow = row("slow");
        assert_eq!(slow.errors, 0);
        assert!((slow.goodput_retained - 1.0).abs() < 1e-12);
        assert!(
            slow.elapsed_secs > none.elapsed_secs,
            "slow fault did not stretch the cell: {} vs {}",
            none.elapsed_secs,
            slow.elapsed_secs
        );
        assert!(slow.goodput_mbps < none.goodput_mbps);
        // Offline mid-run: requests finally fail after the retry
        // budget, so errors and retries are both visible and the
        // retained fraction drops below the baseline.
        let off = row("offline");
        assert!(off.errors > 0, "offline window produced no failures");
        assert!(off.retries > 0, "failures were not retried first");
        assert!(off.goodput_retained < 1.0);
        assert_eq!(off.completed + off.errors, off.submitted);
        assert!(off.fault_clear_secs > off.fault_start_secs);
        // CSV: header + one line per row, constant column count.
        let csv = to_csv(&rows);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 4);
        let ncols = lines[0].split(',').count();
        assert_eq!(ncols, CSV_COLUMNS.len());
        for l in &lines {
            assert_eq!(l.split(',').count(), ncols, "ragged CSV: {l}");
        }
        // JSON round-trips through the in-repo parser.
        let parsed = Json::parse(&to_json(&rows)).unwrap();
        match parsed {
            Json::Arr(objs) => {
                assert_eq!(objs.len(), 3);
                for o in objs {
                    assert!(o.get("kind").and_then(Json::as_str).is_some());
                    assert!(o.get("goodput_retained").is_some());
                }
            }
            other => panic!("expected a JSON array, got {other:?}"),
        }
    }

    #[test]
    fn virtual_cells_are_deterministic() {
        // The §14 bench gate at unit scale: the same cell config under
        // the virtual clock lands on bit-identical makespans and
        // identical error/retry ledgers, run to run.  One worker: a
        // single submitter makes the discrete-event schedule fully
        // ordered (multi-worker submission interleaving is a host
        // scheduler artifact even in virtual time).
        let mut cfg = tiny_cfg();
        cfg.workers = 1;
        let a = run(&cfg).unwrap();
        let b = run(&cfg).unwrap();
        assert_eq!(a.len(), b.len());
        for (ra, rb) in a.iter().zip(&b) {
            assert_eq!(ra.kind, rb.kind);
            assert_eq!(ra.errors, rb.errors, "{}: errors moved", ra.kind);
            assert_eq!(ra.retries, rb.retries, "{}: retries moved", ra.kind);
            assert!(
                (ra.elapsed_secs - rb.elapsed_secs).abs() < 1e-9,
                "{}: makespan not deterministic: {} vs {}",
                ra.kind,
                ra.elapsed_secs,
                rb.elapsed_secs
            );
        }
    }

    #[test]
    fn unknown_kind_and_device_rejected_before_running() {
        let mut cfg = tiny_cfg();
        cfg.kinds = vec!["quantum".into()];
        let err = run(&cfg).unwrap_err().to_string();
        for kind in crate::storage::FAULT_KINDS {
            assert!(
                err.contains(kind),
                "kind error does not list {kind:?}: {err}"
            );
        }
        let mut cfg = tiny_cfg();
        cfg.devices = vec!["floppy".into()];
        let err = run(&cfg).unwrap_err().to_string();
        assert!(
            err.contains("floppy") && err.contains("hdd")
                && err.contains("lustre"),
            "device error does not list valid profiles: {err}"
        );
    }
}
