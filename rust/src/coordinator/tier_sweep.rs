//! `dlio tier-sweep` — the storage-hierarchy characterization driver
//! (DESIGN.md §12).
//!
//! Runs a matrix of (hierarchy preset × placement policy × workload)
//! cells and emits one CSV/JSON row per cell, mirroring `qos-sweep`'s
//! row discipline.  Two workloads:
//!
//! * `hot` — skewed ingest over a corpus homed on the hierarchy's
//!   bottom tier: `hot_frac` of the accesses cycle through a small
//!   hot set.  This is the placement-policy study: a promotion policy
//!   should lift the hot set into tier 0 (higher tier-0 hit fraction)
//!   and unload the slow device's queue (lower ingest p99).
//! * `ckpt` — checkpoint triples saved through the hierarchy (the
//!   paper's §III-C study as sweep cells): a write-through staging
//!   tier returns as soon as the fast copy is durable, so the
//!   training-visible save time against `blackdog-bb` vs
//!   `blackdog-direct-hdd` reproduces the burst-buffer speedup as a
//!   pair of rows.
//!
//! Every cell is self-contained: a fresh sim + hierarchy over the
//! full paper testbed, `IoEngine::reset_stats` bracketing the
//! measured phase.  Unknown hierarchy/policy names fail before any
//! cell runs, listing the valid presets.

use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use crate::config::Testbed;
use crate::data::manifest::Sample;
use crate::model::ModelState;
use crate::pipeline::{sharded_reader_hier, Dataset};
use crate::runtime::meta::{ParamSpec, ProfileMeta};
use crate::storage::{
    policy, profiles, ClockSpec, HierarchySpec, IoClass, SimPath,
    StorageHierarchy, StorageSim, TierKind,
};
use crate::util::json::{obj, to_string, Json};

/// Sweep matrix + workload shape.
#[derive(Debug, Clone)]
pub struct TierSweepConfig {
    /// Hierarchy preset names (`profiles::hierarchy_by_name`).
    pub hierarchies: Vec<String>,
    /// Placement policies for the `hot` workload (`ckpt` cells always
    /// run `noop` — placement of fresh writes is the same for all).
    pub policies: Vec<String>,
    /// Workloads: `hot` | `ckpt`.
    pub workloads: Vec<String>,
    /// Corpus size, files (homed on the bottom tier).
    pub files: usize,
    /// Bytes per corpus file.
    pub file_bytes: usize,
    /// Total measured accesses in the `hot` workload.
    pub reads: usize,
    /// Unmeasured warm-up accesses before the measured phase (same
    /// skew): lets promotion policies converge, so the measured p99
    /// reflects steady-state placement — the adaptive-QoS bench's
    /// warm-up-round protocol.  Hierarchy hit/migration counters span
    /// the whole run; engine queue stats are reset after warm-up.
    pub warmup_reads: usize,
    /// Files in the hot set.
    pub hot_files: usize,
    /// Fraction of accesses that go to the hot set.
    pub hot_frac: f64,
    /// Reader shards / per-shard window for the `hot` workload.
    pub shards: usize,
    pub window: usize,
    /// Override tier 0's byte capacity (0 = preset default) — the
    /// cache-pressure knob.
    pub tier0_cap: u64,
    /// Checkpoint saves in the `ckpt` workload.
    pub ckpt_saves: usize,
    /// Model parameters per checkpoint (sizes the `.data` payload).
    pub ckpt_params: usize,
    /// Simulation speed-up.
    pub time_scale: f64,
    /// Working directory root (each cell gets a subdirectory).
    pub workdir: String,
    /// Time source per cell (virtual = discrete-event, the default).
    pub clock: ClockSpec,
}

impl TierSweepConfig {
    /// Full default matrix.
    pub fn standard(workdir: String, time_scale: f64) -> TierSweepConfig {
        TierSweepConfig {
            hierarchies: vec![
                "tegner-lustre+optane".into(),
                "blackdog-tiered".into(),
                "blackdog-bb".into(),
                "blackdog-direct-hdd".into(),
            ],
            policies: vec!["noop".into(), "lru".into(), "freq".into()],
            workloads: vec!["hot".into(), "ckpt".into()],
            files: 96,
            file_bytes: 64 * 1024,
            reads: 960,
            warmup_reads: 96,
            hot_files: 12,
            hot_frac: 0.8,
            shards: 2,
            window: 4,
            tier0_cap: 24 * 64 * 1024,
            ckpt_saves: 8,
            ckpt_params: 64 * 1024,
            time_scale,
            workdir,
            clock: ClockSpec::Virtual,
        }
    }

    /// Tiny matrix for CI: seconds, not minutes.
    pub fn smoke(workdir: String, time_scale: f64) -> TierSweepConfig {
        TierSweepConfig {
            hierarchies: vec![
                "tegner-lustre+optane".into(),
                "blackdog-bb".into(),
                "blackdog-direct-hdd".into(),
            ],
            policies: vec!["noop".into(), "freq".into()],
            workloads: vec!["hot".into(), "ckpt".into()],
            files: 24,
            file_bytes: 16 * 1024,
            reads: 160,
            warmup_reads: 0,
            hot_files: 4,
            hot_frac: 0.8,
            shards: 2,
            window: 4,
            tier0_cap: 8 * 16 * 1024,
            ckpt_saves: 3,
            ckpt_params: 16 * 1024,
            time_scale,
            workdir,
            clock: ClockSpec::Virtual,
        }
    }
}

/// One (hierarchy, policy, workload) cell.
#[derive(Debug, Clone)]
pub struct TierSweepCell {
    pub hierarchy: String,
    pub policy: String,
    pub workload: String,
    /// Tier count of the hierarchy.
    pub tiers: usize,
    /// Accesses (hot) or saves (ckpt) performed.
    pub ops: u64,
    pub elapsed_secs: f64,
    pub ops_per_sec: f64,
    /// Reads served by tier 0 / total reads (`hot`; 0 for `ckpt`).
    pub t0_hits: u64,
    pub t0_hit_frac: f64,
    /// Migration copies into tier 0 (promotions).
    pub promotions: u64,
    /// Copies dropped from tier 0 (demotions/evictions away).
    pub demotions: u64,
    /// Migration copies into the bottom tier (drains).
    pub drained: u64,
    /// Worst per-device engine ingest p99 queue wait, wall ms.
    pub ingest_p99_ms: f64,
    /// Median / total training-visible save pause (`ckpt`), seconds.
    pub save_p50_secs: f64,
    pub save_total_secs: f64,
    /// Per-tier detail (JSON only).
    pub tier_rows: Vec<TierRow>,
}

/// Per-tier slice of a cell (the hit/migration columns the plot
/// script renders).
#[derive(Debug, Clone)]
pub struct TierRow {
    pub tier: usize,
    pub name: String,
    pub device: String,
    pub hits: u64,
    pub migrations_in: u64,
    pub evictions: u64,
    pub resident_mb: f64,
}

/// CSV column order — one place, so header and rows cannot drift.
const CSV_COLUMNS: [&str; 14] = [
    "hierarchy",
    "policy",
    "workload",
    "tiers",
    "ops",
    "elapsed_secs",
    "ops_per_sec",
    "t0_hits",
    "t0_hit_frac",
    "promotions",
    "demotions",
    "drained",
    "ingest_p99_ms",
    "save_p50_ms",
];

impl TierSweepCell {
    fn csv_row(&self) -> String {
        [
            self.hierarchy.clone(),
            self.policy.clone(),
            self.workload.clone(),
            self.tiers.to_string(),
            self.ops.to_string(),
            format!("{:.4}", self.elapsed_secs),
            format!("{:.1}", self.ops_per_sec),
            self.t0_hits.to_string(),
            format!("{:.4}", self.t0_hit_frac),
            self.promotions.to_string(),
            self.demotions.to_string(),
            self.drained.to_string(),
            format!("{:.4}", self.ingest_p99_ms),
            format!("{:.4}", self.save_p50_secs * 1e3),
        ]
        .join(",")
    }

    fn json_value(&self) -> Json {
        obj(vec![
            ("hierarchy", Json::Str(self.hierarchy.clone())),
            ("policy", Json::Str(self.policy.clone())),
            ("workload", Json::Str(self.workload.clone())),
            ("tiers", Json::Num(self.tiers as f64)),
            ("ops", Json::Num(self.ops as f64)),
            ("elapsed_secs", Json::Num(self.elapsed_secs)),
            ("ops_per_sec", Json::Num(self.ops_per_sec)),
            ("t0_hits", Json::Num(self.t0_hits as f64)),
            ("t0_hit_frac", Json::Num(self.t0_hit_frac)),
            ("promotions", Json::Num(self.promotions as f64)),
            ("demotions", Json::Num(self.demotions as f64)),
            ("drained", Json::Num(self.drained as f64)),
            ("ingest_p99_ms", Json::Num(self.ingest_p99_ms)),
            ("save_p50_ms", Json::Num(self.save_p50_secs * 1e3)),
            ("save_total_secs", Json::Num(self.save_total_secs)),
            (
                "tier_rows",
                Json::Arr(
                    self.tier_rows
                        .iter()
                        .map(|t| {
                            obj(vec![
                                ("tier", Json::Num(t.tier as f64)),
                                ("name", Json::Str(t.name.clone())),
                                ("device", Json::Str(t.device.clone())),
                                ("hits", Json::Num(t.hits as f64)),
                                (
                                    "migrations_in",
                                    Json::Num(t.migrations_in as f64),
                                ),
                                ("evictions", Json::Num(t.evictions as f64)),
                                ("resident_mb", Json::Num(t.resident_mb)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Render cells as CSV (header + one line per cell).
pub fn to_csv(cells: &[TierSweepCell]) -> String {
    let mut out = CSV_COLUMNS.join(",");
    out.push('\n');
    for c in cells {
        out.push_str(&c.csv_row());
        out.push('\n');
    }
    out
}

/// Render cells as a JSON array (one object per cell, with per-tier
/// rows).
pub fn to_json(cells: &[TierSweepCell]) -> String {
    to_string(&Json::Arr(cells.iter().map(|c| c.json_value()).collect()))
}

/// Resolve a hierarchy preset (with the tier-0 capacity override),
/// listing the valid names on a typo — the same contract as profile
/// errors.
fn spec_for(cfg: &TierSweepConfig, name: &str) -> Result<HierarchySpec> {
    let mut spec = profiles::hierarchy_by_name(name).ok_or_else(|| {
        anyhow!(
            "unknown hierarchy {name:?} (valid: {})",
            profiles::HIERARCHY_NAMES.join(", ")
        )
    })?;
    if cfg.tier0_cap > 0 && spec.tiers.len() > 1 {
        spec.tiers[0].capacity = cfg.tier0_cap;
    }
    Ok(spec)
}

/// Run the full matrix; cells in (workload, hierarchy, policy) order.
pub fn run(cfg: &TierSweepConfig) -> Result<Vec<TierSweepCell>> {
    // Validate the whole matrix before the first cell.
    for h in &cfg.hierarchies {
        let _ = spec_for(cfg, h)?;
    }
    for p in &cfg.policies {
        let _ = policy::by_name(p)?;
    }
    let noop = vec!["noop".to_string()];
    let mut cells = Vec::new();
    for workload in &cfg.workloads {
        let policies = match workload.as_str() {
            "hot" => &cfg.policies,
            "ckpt" => &noop,
            other => {
                return Err(anyhow!(
                    "unknown workload {other:?} (valid: hot, ckpt)"
                ))
            }
        };
        for hierarchy in &cfg.hierarchies {
            for pol in policies {
                cells.push(run_cell(cfg, hierarchy, pol, workload)?);
            }
        }
    }
    Ok(cells)
}

/// Bottom (slowest) device tier of a spec.
fn bottom_device_tier(spec: &HierarchySpec) -> usize {
    (0..spec.tiers.len())
        .rev()
        .find(|&i| matches!(spec.tiers[i].kind, TierKind::Device(_)))
        .expect("validated: every hierarchy has a device tier")
}

fn run_cell(
    cfg: &TierSweepConfig,
    hierarchy: &str,
    pol: &str,
    workload: &str,
) -> Result<TierSweepCell> {
    let spec = spec_for(cfg, hierarchy)?;
    let dir = std::path::Path::new(&cfg.workdir)
        .join(format!("tier-sweep-{hierarchy}-{pol}-{workload}"));
    let _ = std::fs::remove_dir_all(&dir);
    let tb = Testbed::paper(cfg.time_scale);
    let sim = Arc::new(StorageSim::cold_with_qos_clock(
        dir,
        tb.devices,
        crate::storage::QosConfig::default(),
        cfg.clock.build(),
    )?);
    let tiers = spec.tiers.len();
    let bottom = bottom_device_tier(&spec);
    let hier = Arc::new(StorageHierarchy::new(
        Arc::clone(&sim),
        spec,
        policy::by_name(pol)?,
    )?);

    let mut cell = TierSweepCell {
        hierarchy: hierarchy.to_string(),
        policy: hier.policy_name().to_string(),
        workload: workload.to_string(),
        tiers,
        ops: 0,
        elapsed_secs: 0.0,
        ops_per_sec: 0.0,
        t0_hits: 0,
        t0_hit_frac: 0.0,
        promotions: 0,
        demotions: 0,
        drained: 0,
        ingest_p99_ms: 0.0,
        save_p50_secs: 0.0,
        save_total_secs: 0.0,
        tier_rows: Vec::new(),
    };

    match workload {
        "hot" => run_hot(cfg, &sim, &hier, bottom, &mut cell)?,
        "ckpt" => run_ckpt(cfg, &sim, &hier, &mut cell)?,
        _ => unreachable!("validated in run()"),
    }

    // Flush pending migrations so tier rows are final, then snapshot.
    hier.wait_idle();
    let stats = hier.stats();
    cell.t0_hits = stats[0].hits;
    let total_reads = hier.total_reads();
    cell.t0_hit_frac = if total_reads > 0 {
        stats[0].hits as f64 / total_reads as f64
    } else {
        0.0
    };
    cell.promotions = stats[0].migrations_in;
    cell.demotions = stats[0].evictions;
    cell.drained = if bottom > 0 { stats[bottom].migrations_in } else { 0 };
    cell.ingest_p99_ms = sim
        .engine()
        .stats()
        .iter()
        .map(|s| s.class(IoClass::Ingest).p99_queue_secs())
        .fold(0.0, f64::max)
        * 1e3;
    cell.tier_rows = stats
        .iter()
        .map(|s| TierRow {
            tier: s.tier,
            name: s.name.clone(),
            device: s.device.clone().unwrap_or_else(|| "ram".into()),
            hits: s.hits,
            migrations_in: s.migrations_in,
            evictions: s.evictions,
            resident_mb: s.resident_bytes as f64 / 1e6,
        })
        .collect();
    cell.ops_per_sec = if cell.elapsed_secs > 0.0 {
        cell.ops as f64 / cell.elapsed_secs
    } else {
        0.0
    };
    Ok(cell)
}

/// Skewed ingest: `hot_frac` of `reads` accesses cycle through the
/// first `hot_files` files, the rest through the cold tail, in a
/// deterministic interleave.
fn run_hot(
    cfg: &TierSweepConfig,
    sim: &Arc<StorageSim>,
    hier: &Arc<StorageHierarchy>,
    bottom: usize,
    cell: &mut TierSweepCell,
) -> Result<()> {
    let bottom_dev = hier.device_of(bottom)?;
    // Register the driver with the sim's clock for the whole cell:
    // virtual time advances only while we block on tickets.
    let clock = sim.clock().clone();
    let _reg = clock.enter();
    let files = cfg.files.max(2);
    let hot_n = cfg.hot_files.clamp(1, files - 1);
    // Fixture: corpus homed on the bottom tier.
    let mut samples = Vec::with_capacity(files);
    for i in 0..files {
        let key = format!("corpus/f{i}.bin");
        let p = SimPath::new(bottom_dev.clone(), key.clone());
        sim.write(&p, &vec![(i % 251) as u8; cfg.file_bytes])?;
        hier.register(&key, cfg.file_bytes as u64, bottom)?;
        samples.push(Sample {
            path: SimPath::new(bottom_dev.clone(), key),
            label: i as u32,
        });
    }
    sim.drop_caches();

    // Access stream: a deterministic integer error-diffusion
    // interleave (millionths) that realizes `hot_frac` exactly for
    // any CLI-typed fraction — `--hot-frac 0.84` runs 84%, not a
    // tenth-quantized 80%.  A slot is hot when the accumulator
    // crosses 1.
    let step = (cfg.hot_frac * 1e6).round() as u64;
    let total = cfg.warmup_reads + cfg.reads;
    let mut accesses = Vec::with_capacity(total);
    let (mut hi, mut ci) = (0usize, 0usize);
    let mut acc = 0u64;
    for _ in 0..total {
        acc += step;
        if acc >= 1_000_000 {
            acc -= 1_000_000;
            accesses.push(samples[hi % hot_n].clone());
            hi += 1;
        } else {
            accesses.push(samples[hot_n + ci % (files - hot_n)].clone());
            ci += 1;
        }
    }
    let measured = accesses.split_off(cfg.warmup_reads);

    // Warm-up (unmeasured): run the same skew and let any pending
    // promotions land, so the measured phase sees the converged
    // placement.
    if !accesses.is_empty() {
        let mut ds = sharded_reader_hier(
            accesses,
            Arc::clone(hier),
            cfg.shards,
            cfg.window,
        );
        while let Some(item) = ds.next() {
            item.context("tier-sweep warm-up read failed")?;
        }
        hier.wait_idle();
    }
    sim.engine().reset_stats();

    let t0 = clock.now();
    let mut ds = sharded_reader_hier(
        measured,
        Arc::clone(hier),
        cfg.shards,
        cfg.window,
    );
    let mut n = 0u64;
    while let Some(item) = ds.next() {
        item.context("tier-sweep hot read failed")?;
        n += 1;
    }
    cell.ops = n;
    cell.elapsed_secs = clock.now() - t0;
    Ok(())
}

/// Checkpoint saves routed through the hierarchy: the placement
/// policy lands triples on tier 0; write-through presets drain them
/// down in the background — the save pause is the fast tier only.
fn run_ckpt(
    cfg: &TierSweepConfig,
    sim: &Arc<StorageSim>,
    hier: &Arc<StorageHierarchy>,
    cell: &mut TierSweepCell,
) -> Result<()> {
    let params = cfg.ckpt_params.max(16);
    let profile = ProfileMeta {
        name: "sweep".into(),
        input_size: 8,
        num_classes: 4,
        num_params: params,
        params: vec![ParamSpec {
            name: "fc1/kernel".into(),
            shape: vec![params],
        }],
    };
    let state = ModelState::init(&profile, 7);
    let mut saver = crate::checkpoint::Saver::new(
        Arc::clone(sim),
        profile,
        &hier.write_placement().1,
        "ckpt/model",
        cfg.ckpt_saves.max(1),
    );
    saver.set_route(Arc::clone(hier));
    saver.sync_on_save = false;
    sim.engine().reset_stats();
    // Save pauses are clock durations (wall or virtual alike).
    let clock = sim.clock().clone();
    let _reg = clock.enter();
    let mut durations = Vec::with_capacity(cfg.ckpt_saves);
    let total0 = clock.now();
    for s in 0..cfg.ckpt_saves.max(1) as u64 {
        let t0 = clock.now();
        saver.save(&state, (s + 1) * 10)?;
        durations.push(clock.now() - t0);
    }
    cell.save_total_secs = clock.now() - total0;
    cell.elapsed_secs = cell.save_total_secs;
    cell.ops = durations.len() as u64;
    cell.save_p50_secs = crate::metrics::median(&mut durations);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg(tag: &str) -> TierSweepConfig {
        let dir = std::env::temp_dir().join(format!(
            "dlio-tier-sweep-test-{tag}-{}",
            std::process::id()
        ));
        TierSweepConfig {
            hierarchies: vec![
                "tegner-lustre+optane".into(),
                "blackdog-direct-hdd".into(),
            ],
            policies: vec!["noop".into(), "freq".into()],
            workloads: vec!["hot".into()],
            files: 10,
            file_bytes: 4 * 1024,
            reads: 50,
            warmup_reads: 0,
            hot_files: 2,
            hot_frac: 0.8,
            shards: 2,
            window: 2,
            tier0_cap: 6 * 4 * 1024,
            ckpt_saves: 2,
            ckpt_params: 1024,
            // Modest acceleration: reads stay slow enough (tens of
            // µs+) that the async migrator visibly interleaves with
            // the access stream — the property the freq test gates.
            time_scale: 8.0,
            workdir: dir.to_string_lossy().into_owned(),
            clock: ClockSpec::Virtual,
        }
    }

    #[test]
    fn sweep_emits_one_row_per_cell_with_sane_fields() {
        let mut cfg = tiny_cfg("rows");
        cfg.workloads = vec!["hot".into(), "ckpt".into()];
        let cells = run(&cfg).unwrap();
        // hot: 2 hierarchies x 2 policies; ckpt: 2 hierarchies x noop.
        assert_eq!(cells.len(), 6);
        for c in &cells {
            match c.workload.as_str() {
                "hot" => {
                    assert_eq!(c.ops, 50, "every access read exactly once");
                    assert!(c.t0_hit_frac >= 0.0 && c.t0_hit_frac <= 1.0);
                    if c.hierarchy == "blackdog-direct-hdd" {
                        // Single tier: everything is a tier-0 hit.
                        assert_eq!(c.t0_hit_frac, 1.0);
                    }
                }
                "ckpt" => {
                    assert_eq!(c.ops, 2);
                    assert!(c.save_p50_secs > 0.0);
                }
                other => panic!("unexpected workload {other}"),
            }
            assert!(c.elapsed_secs > 0.0);
            assert_eq!(c.tier_rows.len(), c.tiers);
        }
        // CSV: header + one line per cell, constant column count.
        let csv = to_csv(&cells);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 7);
        let ncols = lines[0].split(',').count();
        for l in &lines {
            assert_eq!(l.split(',').count(), ncols, "ragged CSV: {l}");
        }
        // JSON round-trips through the in-repo parser with tier rows.
        let parsed = Json::parse(&to_json(&cells)).unwrap();
        match parsed {
            Json::Arr(rows) => {
                assert_eq!(rows.len(), 6);
                for r in rows {
                    assert!(r.get("hierarchy").and_then(Json::as_str).is_some());
                    let tiers = r
                        .get("tier_rows")
                        .and_then(Json::as_arr)
                        .expect("tier_rows array");
                    assert!(!tiers.is_empty());
                }
            }
            other => panic!("expected a JSON array, got {other:?}"),
        }
    }

    #[test]
    fn frequency_beats_noop_on_the_hot_set() {
        // The tentpole's acceptance property at unit scale: on the
        // 2-tier cache hierarchy, the promotion policy must lift the
        // tier-0 hit fraction strictly above noop's (which never
        // promotes, so its only tier-0 hits would be impossible —
        // the corpus is homed below).
        let mut cfg = tiny_cfg("freqwins");
        cfg.hierarchies = vec!["tegner-lustre+optane".into()];
        let cells = run(&cfg).unwrap();
        assert_eq!(cells.len(), 2);
        let noop = cells.iter().find(|c| c.policy == "noop").unwrap();
        let freq = cells.iter().find(|c| c.policy == "freq").unwrap();
        assert_eq!(noop.t0_hit_frac, 0.0, "noop never promotes");
        assert!(
            freq.t0_hit_frac > 0.3,
            "freq hit frac {:.2} did not capture the hot set",
            freq.t0_hit_frac
        );
        assert!(freq.promotions > 0);
    }

    #[test]
    fn unknown_names_fail_fast_listing_presets() {
        let mut cfg = tiny_cfg("badname");
        cfg.hierarchies = vec!["blackdog-floppy".into()];
        let err = run(&cfg).unwrap_err().to_string();
        assert!(
            err.contains("blackdog-bb") && err.contains("tegner"),
            "hierarchy error does not list presets: {err}"
        );
        let mut cfg = tiny_cfg("badpolicy");
        cfg.policies = vec!["banana".into()];
        let err = run(&cfg).unwrap_err().to_string();
        assert!(err.contains("noop"), "policy error lists names: {err}");
        let mut cfg = tiny_cfg("badworkload");
        cfg.workloads = vec!["warp".into()];
        assert!(run(&cfg).is_err());
    }
}
