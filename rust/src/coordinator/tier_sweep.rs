//! `dlio tier-sweep` — the storage-hierarchy characterization driver
//! (DESIGN.md §12).
//!
//! Runs a matrix of (hierarchy preset × placement policy × workload)
//! cells and emits one CSV/JSON row per cell, mirroring `qos-sweep`'s
//! row discipline.  Four workloads:
//!
//! * `hot` — skewed ingest over a corpus homed on the hierarchy's
//!   bottom tier: `hot_frac` of the accesses cycle through a small
//!   hot set.  This is the placement-policy study: a promotion policy
//!   should lift the hot set into tier 0 (higher tier-0 hit fraction)
//!   and unload the slow device's queue (lower ingest p99).
//! * `zipf` (alias `zipf:<theta>`) — a Zipf(theta) read-write mix
//!   from [`mixed_accesses`]: ranks draw with weight
//!   `1/(i+1)^theta`, writes update the bottom-tier home and
//!   invalidate promoted copies.  The working-set-to-tier-0 ratio
//!   (`ws_ratio`) sizes tier 0 below the corpus, so policies are
//!   judged under capacity pressure — the cost-aware placement study.
//! * `uniform` — the same mix with theta 0 (no skew): the control
//!   cell where promotion cannot help and a cost model should mostly
//!   reject migrations.
//! * `ckpt` — checkpoint triples saved through the hierarchy (the
//!   paper's §III-C study as sweep cells): a write-through staging
//!   tier returns as soon as the fast copy is durable, so the
//!   training-visible save time against `blackdog-bb` vs
//!   `blackdog-direct-hdd` reproduces the burst-buffer speedup as a
//!   pair of rows.
//!
//! Every cell is self-contained: a fresh sim + hierarchy over the
//! full paper testbed, `IoEngine::reset_stats` bracketing the
//! measured phase; mix streams are seeded, so virtual-clock cells are
//! bit-deterministic.  Unknown hierarchy/policy/workload names fail
//! before any cell runs, listing the valid presets.

use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use super::workload::{mixed_accesses, MixOp};
use crate::config::Testbed;
use crate::data::manifest::Sample;
use crate::model::ModelState;
use crate::pipeline::{sharded_reader_hier, Dataset};
use crate::runtime::meta::{ParamSpec, ProfileMeta};
use crate::storage::{
    policy, profiles, ClockSpec, EngineOp, HierarchySpec, IoClass,
    SimPath, StorageHierarchy, StorageSim, TierKind,
};
use crate::trace::{Trace, TraceEvent};
use crate::util::json::{obj, to_string, Json};

/// Sweep matrix + workload shape.
#[derive(Debug, Clone)]
pub struct TierSweepConfig {
    /// Hierarchy preset names (`profiles::hierarchy_by_name`).
    pub hierarchies: Vec<String>,
    /// Placement policies for the `hot` workload (`ckpt` cells always
    /// run `noop` — placement of fresh writes is the same for all).
    pub policies: Vec<String>,
    /// Workloads: `hot` | `ckpt` | `zipf[:theta]` | `uniform`.
    pub workloads: Vec<String>,
    /// Corpus size, files (homed on the bottom tier).
    pub files: usize,
    /// Bytes per corpus file.
    pub file_bytes: usize,
    /// Total measured accesses in the `hot` workload.
    pub reads: usize,
    /// Unmeasured warm-up accesses before the measured phase (same
    /// skew): lets promotion policies converge, so the measured p99
    /// reflects steady-state placement — the adaptive-QoS bench's
    /// warm-up-round protocol.  Hierarchy hit/migration counters span
    /// the whole run; engine queue stats are reset after warm-up.
    pub warmup_reads: usize,
    /// Files in the hot set.
    pub hot_files: usize,
    /// Fraction of accesses that go to the hot set.
    pub hot_frac: f64,
    /// Reader shards / per-shard window for the `hot` workload.
    pub shards: usize,
    pub window: usize,
    /// Override tier 0's byte capacity (0 = preset default) — the
    /// cache-pressure knob (`hot` cells; mix cells use `ws_ratio`).
    pub tier0_cap: u64,
    /// Zipf skew for bare `zipf` workload tokens (a `zipf:1.2` token
    /// overrides per cell).
    pub theta: f64,
    /// Read fraction of the mix workloads (1.0 = read-only).
    pub rw_ratio: f64,
    /// Open-loop pacing between mix ops, microseconds of modelled
    /// time (0 = closed loop).  Scaled by `time_scale` like device
    /// latencies.
    pub arrival_us: f64,
    /// Working-set-to-tier-0 ratio for mix cells: tier 0's capacity
    /// is set to `corpus_bytes / ws_ratio` (0 = leave the preset /
    /// `tier0_cap` value).  Ratios above 1 put the corpus under
    /// capacity pressure — the regime where placement cost matters.
    pub ws_ratio: f64,
    /// Checkpoint saves in the `ckpt` workload.
    pub ckpt_saves: usize,
    /// Model parameters per checkpoint (sizes the `.data` payload).
    pub ckpt_params: usize,
    /// Simulation speed-up.
    pub time_scale: f64,
    /// Working directory root (each cell gets a subdirectory).
    pub workdir: String,
    /// Time source per cell (virtual = discrete-event, the default).
    pub clock: ClockSpec,
}

impl TierSweepConfig {
    /// Full default matrix.
    pub fn standard(workdir: String, time_scale: f64) -> TierSweepConfig {
        TierSweepConfig {
            hierarchies: vec![
                "tegner-lustre+optane".into(),
                "blackdog-tiered".into(),
                "blackdog-bb".into(),
                "blackdog-direct-hdd".into(),
                "calibrated-tiered".into(),
            ],
            policies: vec![
                "noop".into(),
                "lru".into(),
                "freq".into(),
                "cost".into(),
            ],
            workloads: vec![
                "hot".into(),
                "zipf".into(),
                "uniform".into(),
                "ckpt".into(),
            ],
            files: 96,
            file_bytes: 64 * 1024,
            reads: 960,
            warmup_reads: 96,
            hot_files: 12,
            hot_frac: 0.8,
            shards: 2,
            window: 4,
            tier0_cap: 24 * 64 * 1024,
            theta: 0.9,
            rw_ratio: 0.9,
            arrival_us: 0.0,
            ws_ratio: 3.0,
            ckpt_saves: 8,
            ckpt_params: 64 * 1024,
            time_scale,
            workdir,
            clock: ClockSpec::Virtual,
        }
    }

    /// Tiny matrix for CI: seconds, not minutes.
    pub fn smoke(workdir: String, time_scale: f64) -> TierSweepConfig {
        TierSweepConfig {
            hierarchies: vec![
                "tegner-lustre+optane".into(),
                "blackdog-bb".into(),
                "blackdog-direct-hdd".into(),
            ],
            policies: vec!["noop".into(), "freq".into(), "cost".into()],
            workloads: vec!["hot".into(), "zipf".into(), "ckpt".into()],
            files: 24,
            file_bytes: 16 * 1024,
            reads: 160,
            warmup_reads: 0,
            hot_files: 4,
            hot_frac: 0.8,
            shards: 2,
            window: 4,
            tier0_cap: 8 * 16 * 1024,
            theta: 0.9,
            rw_ratio: 0.9,
            arrival_us: 0.0,
            ws_ratio: 3.0,
            ckpt_saves: 3,
            ckpt_params: 16 * 1024,
            time_scale,
            workdir,
            clock: ClockSpec::Virtual,
        }
    }
}

/// One (hierarchy, policy, workload) cell.
#[derive(Debug, Clone)]
pub struct TierSweepCell {
    pub hierarchy: String,
    pub policy: String,
    pub workload: String,
    /// Tier count of the hierarchy.
    pub tiers: usize,
    /// Accesses (hot) or saves (ckpt) performed.
    pub ops: u64,
    pub elapsed_secs: f64,
    pub ops_per_sec: f64,
    /// Reads served by tier 0 / total reads (`hot`; 0 for `ckpt`).
    pub t0_hits: u64,
    pub t0_hit_frac: f64,
    /// Migration copies into tier 0 (promotions).
    pub promotions: u64,
    /// Copies dropped from tier 0 (demotions/evictions away).
    pub demotions: u64,
    /// Migration copies into the bottom tier (drains).
    pub drained: u64,
    /// Worst per-device engine ingest p99 queue wait, wall ms.
    pub ingest_p99_ms: f64,
    /// Median / total training-visible save pause (`ckpt`), seconds.
    pub save_p50_secs: f64,
    pub save_total_secs: f64,
    /// Zipf skew of a mix cell (0 for `uniform`/`hot`/`ckpt`).
    pub theta: f64,
    /// Drain-class bytes landed on any device since warm-up, MB —
    /// the migration traffic the policy generated.
    pub migration_mb: f64,
    /// Policy-predicted migration seconds over the measured phase
    /// (cost-aware policies only; 0 otherwise).
    pub predicted_migration_secs: f64,
    /// Predicted / measured Drain-class service seconds: how well
    /// the policy's cost model priced the migrations it approved
    /// (1.0 = perfectly calibrated; 0 when the policy prices
    /// nothing).
    pub cost_accuracy: f64,
    /// Candidate promotions the policy rejected as not worth their
    /// migration cost.
    pub rejected_by_cost: u64,
    /// Per-tier detail (JSON only).
    pub tier_rows: Vec<TierRow>,
}

/// Per-tier slice of a cell (the hit/migration columns the plot
/// script renders).
#[derive(Debug, Clone)]
pub struct TierRow {
    pub tier: usize,
    pub name: String,
    pub device: String,
    pub hits: u64,
    pub migrations_in: u64,
    pub evictions: u64,
    pub resident_mb: f64,
}

/// CSV column order — one place, so header and rows cannot drift.
const CSV_COLUMNS: [&str; 18] = [
    "hierarchy",
    "policy",
    "workload",
    "theta",
    "tiers",
    "ops",
    "elapsed_secs",
    "ops_per_sec",
    "t0_hits",
    "t0_hit_frac",
    "promotions",
    "demotions",
    "rejected_by_cost",
    "drained",
    "migration_mb",
    "cost_accuracy",
    "ingest_p99_ms",
    "save_p50_ms",
];

impl TierSweepCell {
    fn csv_row(&self) -> String {
        [
            self.hierarchy.clone(),
            self.policy.clone(),
            self.workload.clone(),
            format!("{:.3}", self.theta),
            self.tiers.to_string(),
            self.ops.to_string(),
            format!("{:.4}", self.elapsed_secs),
            format!("{:.1}", self.ops_per_sec),
            self.t0_hits.to_string(),
            format!("{:.4}", self.t0_hit_frac),
            self.promotions.to_string(),
            self.demotions.to_string(),
            self.rejected_by_cost.to_string(),
            self.drained.to_string(),
            format!("{:.4}", self.migration_mb),
            format!("{:.4}", self.cost_accuracy),
            format!("{:.4}", self.ingest_p99_ms),
            format!("{:.4}", self.save_p50_secs * 1e3),
        ]
        .join(",")
    }

    fn json_value(&self) -> Json {
        obj(vec![
            ("hierarchy", Json::Str(self.hierarchy.clone())),
            ("policy", Json::Str(self.policy.clone())),
            ("workload", Json::Str(self.workload.clone())),
            ("theta", Json::Num(self.theta)),
            ("tiers", Json::Num(self.tiers as f64)),
            ("ops", Json::Num(self.ops as f64)),
            ("elapsed_secs", Json::Num(self.elapsed_secs)),
            ("ops_per_sec", Json::Num(self.ops_per_sec)),
            ("t0_hits", Json::Num(self.t0_hits as f64)),
            ("t0_hit_frac", Json::Num(self.t0_hit_frac)),
            ("promotions", Json::Num(self.promotions as f64)),
            ("demotions", Json::Num(self.demotions as f64)),
            (
                "rejected_by_cost",
                Json::Num(self.rejected_by_cost as f64),
            ),
            ("drained", Json::Num(self.drained as f64)),
            ("migration_mb", Json::Num(self.migration_mb)),
            (
                "predicted_migration_secs",
                Json::Num(self.predicted_migration_secs),
            ),
            ("cost_accuracy", Json::Num(self.cost_accuracy)),
            ("ingest_p99_ms", Json::Num(self.ingest_p99_ms)),
            ("save_p50_ms", Json::Num(self.save_p50_secs * 1e3)),
            ("save_total_secs", Json::Num(self.save_total_secs)),
            (
                "tier_rows",
                Json::Arr(
                    self.tier_rows
                        .iter()
                        .map(|t| {
                            obj(vec![
                                ("tier", Json::Num(t.tier as f64)),
                                ("name", Json::Str(t.name.clone())),
                                ("device", Json::Str(t.device.clone())),
                                ("hits", Json::Num(t.hits as f64)),
                                (
                                    "migrations_in",
                                    Json::Num(t.migrations_in as f64),
                                ),
                                ("evictions", Json::Num(t.evictions as f64)),
                                ("resident_mb", Json::Num(t.resident_mb)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Render cells as CSV (header + one line per cell).
pub fn to_csv(cells: &[TierSweepCell]) -> String {
    let mut out = CSV_COLUMNS.join(",");
    out.push('\n');
    for c in cells {
        out.push_str(&c.csv_row());
        out.push('\n');
    }
    out
}

/// Render cells as a JSON array (one object per cell, with per-tier
/// rows).
pub fn to_json(cells: &[TierSweepCell]) -> String {
    to_string(&Json::Arr(cells.iter().map(|c| c.json_value()).collect()))
}

/// Resolve a hierarchy preset (with the tier-0 capacity override),
/// listing the valid names on a typo — the same contract as profile
/// errors.
fn spec_for(cfg: &TierSweepConfig, name: &str) -> Result<HierarchySpec> {
    let mut spec = profiles::hierarchy_by_name(name).ok_or_else(|| {
        anyhow!(
            "unknown hierarchy {name:?} (valid: {})",
            profiles::HIERARCHY_NAMES.join(", ")
        )
    })?;
    if cfg.tier0_cap > 0 && spec.tiers.len() > 1 {
        spec.tiers[0].capacity = cfg.tier0_cap;
    }
    Ok(spec)
}

/// Workload tokens accepted by [`run`] (`zipf` also accepts an
/// inline skew, `zipf:<theta>`).
pub const WORKLOAD_NAMES: [&str; 4] = ["hot", "ckpt", "zipf", "uniform"];

/// A parsed workload token.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Workload {
    Hot,
    Ckpt,
    /// Zipf(theta) read-write mix.
    Zipf(f64),
    /// Uniform read-write mix (Zipf with theta 0).
    Uniform,
}

/// Parse a workload token, erroring with the full valid list — the
/// same fail-before-any-cell contract as hierarchy/policy names.
fn parse_workload(token: &str, default_theta: f64) -> Result<Workload> {
    let bad = || {
        anyhow!(
            "unknown workload {token:?} (valid: {}; zipf takes an \
             optional skew, e.g. zipf:1.2)",
            WORKLOAD_NAMES.join(", ")
        )
    };
    match token {
        "hot" => Ok(Workload::Hot),
        "ckpt" => Ok(Workload::Ckpt),
        "uniform" => Ok(Workload::Uniform),
        "zipf" => Ok(Workload::Zipf(default_theta)),
        other => {
            let theta = other
                .strip_prefix("zipf:")
                .and_then(|t| t.parse::<f64>().ok())
                .filter(|t| t.is_finite() && *t >= 0.0)
                .ok_or_else(bad)?;
            Ok(Workload::Zipf(theta))
        }
    }
}

/// Run the full matrix; cells in (workload, hierarchy, policy) order.
pub fn run(cfg: &TierSweepConfig) -> Result<Vec<TierSweepCell>> {
    // Validate the whole matrix — hierarchies, policies AND workload
    // tokens — before the first cell, so a typo in any axis cannot
    // waste a half-finished sweep.
    for h in &cfg.hierarchies {
        let _ = spec_for(cfg, h)?;
    }
    for p in &cfg.policies {
        let _ = policy::by_name(p)?;
    }
    for w in &cfg.workloads {
        let _ = parse_workload(w, cfg.theta)?;
    }
    let noop = vec!["noop".to_string()];
    let mut cells = Vec::new();
    for workload in &cfg.workloads {
        let parsed = parse_workload(workload, cfg.theta)?;
        let policies = match parsed {
            Workload::Ckpt => &noop,
            _ => &cfg.policies,
        };
        for hierarchy in &cfg.hierarchies {
            for pol in policies {
                cells.push(run_cell(cfg, hierarchy, pol, workload, parsed)?);
            }
        }
    }
    Ok(cells)
}

/// Bottom (slowest) device tier of a spec.
fn bottom_device_tier(spec: &HierarchySpec) -> usize {
    (0..spec.tiers.len())
        .rev()
        .find(|&i| matches!(spec.tiers[i].kind, TierKind::Device(_)))
        .expect("validated: every hierarchy has a device tier")
}

fn run_cell(
    cfg: &TierSweepConfig,
    hierarchy: &str,
    pol: &str,
    workload: &str,
    parsed: Workload,
) -> Result<TierSweepCell> {
    let mut spec = spec_for(cfg, hierarchy)?;
    // Mix cells size tier 0 relative to the corpus: the
    // working-set-to-tier-0 ratio is the pressure axis the
    // cost-aware study sweeps.
    if matches!(parsed, Workload::Zipf(_) | Workload::Uniform)
        && cfg.ws_ratio > 0.0
        && spec.tiers.len() > 1
    {
        let corpus = (cfg.files.max(2) * cfg.file_bytes) as f64;
        spec.tiers[0].capacity =
            ((corpus / cfg.ws_ratio) as u64).max(cfg.file_bytes as u64);
    }
    let dir = std::path::Path::new(&cfg.workdir)
        .join(format!("tier-sweep-{hierarchy}-{pol}-{workload}"));
    let _ = std::fs::remove_dir_all(&dir);
    let tb = Testbed::paper(cfg.time_scale);
    let sim = Arc::new(StorageSim::cold_with_qos_clock(
        dir,
        tb.devices,
        crate::storage::QosConfig::default(),
        cfg.clock.build(),
    )?);
    let tiers = spec.tiers.len();
    let bottom = bottom_device_tier(&spec);
    let hier = Arc::new(StorageHierarchy::new(
        Arc::clone(&sim),
        spec,
        policy::by_name(pol)?,
    )?);

    let mut cell = TierSweepCell {
        hierarchy: hierarchy.to_string(),
        policy: hier.policy_name().to_string(),
        workload: workload.to_string(),
        tiers,
        ops: 0,
        elapsed_secs: 0.0,
        ops_per_sec: 0.0,
        t0_hits: 0,
        t0_hit_frac: 0.0,
        promotions: 0,
        demotions: 0,
        drained: 0,
        ingest_p99_ms: 0.0,
        save_p50_secs: 0.0,
        save_total_secs: 0.0,
        theta: match parsed {
            Workload::Zipf(t) => t,
            _ => 0.0,
        },
        migration_mb: 0.0,
        predicted_migration_secs: 0.0,
        cost_accuracy: 0.0,
        rejected_by_cost: 0,
        tier_rows: Vec::new(),
    };

    match parsed {
        Workload::Hot => run_hot(cfg, &sim, &hier, bottom, &mut cell)?,
        Workload::Ckpt => run_ckpt(cfg, &sim, &hier, &mut cell)?,
        Workload::Zipf(theta) => {
            run_mix(cfg, &sim, &hier, bottom, theta, &mut cell)?
        }
        Workload::Uniform => {
            run_mix(cfg, &sim, &hier, bottom, 0.0, &mut cell)?
        }
    }

    snapshot_cell(&sim, &hier, bottom, &mut cell);
    Ok(cell)
}

/// Finalize a cell after its workload ran: flush pending migrations
/// so tier rows are final, then snapshot hierarchy + engine stats
/// (shared by synthetic and trace-driven cells).
fn snapshot_cell(
    sim: &Arc<StorageSim>,
    hier: &Arc<StorageHierarchy>,
    bottom: usize,
    cell: &mut TierSweepCell,
) {
    hier.wait_idle();
    let stats = hier.stats();
    cell.t0_hits = stats[0].hits;
    let total_reads = hier.total_reads();
    cell.t0_hit_frac = if total_reads > 0 {
        stats[0].hits as f64 / total_reads as f64
    } else {
        0.0
    };
    cell.promotions = stats[0].migrations_in;
    cell.demotions = stats[0].evictions;
    cell.drained = if bottom > 0 { stats[bottom].migrations_in } else { 0 };
    let engine_stats = sim.engine().stats();
    cell.ingest_p99_ms = engine_stats
        .iter()
        .map(|s| s.class(IoClass::Ingest).p99_queue_secs())
        .fold(0.0, f64::max)
        * 1e3;
    // Migration traffic + cost-model accuracy: Drain-class engine
    // stats cover everything since the post-warm-up reset, the same
    // window `predicted_migration_secs` was accumulated over.
    let drain_secs: f64 = engine_stats
        .iter()
        .map(|s| s.class(IoClass::Drain).service_secs)
        .sum();
    cell.migration_mb = engine_stats
        .iter()
        .map(|s| s.class(IoClass::Drain).bytes_written)
        .sum::<u64>() as f64
        / 1e6;
    cell.cost_accuracy =
        if drain_secs > 0.0 && cell.predicted_migration_secs > 0.0 {
            cell.predicted_migration_secs / drain_secs
        } else {
            0.0
        };
    cell.rejected_by_cost = hier.policy_decisions().rejected_by_cost;
    cell.tier_rows = stats
        .iter()
        .map(|s| TierRow {
            tier: s.tier,
            name: s.name.clone(),
            device: s.device.clone().unwrap_or_else(|| "ram".into()),
            hits: s.hits,
            migrations_in: s.migrations_in,
            evictions: s.evictions,
            resident_mb: s.resident_bytes as f64 / 1e6,
        })
        .collect();
    cell.ops_per_sec = if cell.elapsed_secs > 0.0 {
        cell.ops as f64 / cell.elapsed_secs
    } else {
        0.0
    };
}

/// Smallest period `p` of a sequence: `sig[i] == sig[i - p]` for all
/// `i >= p` (a trailing partial repetition is fine).  Computed as
/// `n - longest_border(sig)` via the KMP prefix function, O(n).
/// Epoch-structured training recordings repeat the same (device,
/// bytes) read signature every epoch, so the first `p` events
/// enumerate the distinct blocks; an aperiodic recording degenerates
/// to `p == n` (every event its own block).
fn epoch_period<T: PartialEq>(sig: &[T]) -> usize {
    let n = sig.len();
    if n == 0 {
        return 1;
    }
    let mut pi = vec![0usize; n];
    for i in 1..n {
        let mut k = pi[i - 1];
        while k > 0 && sig[i] != sig[k] {
            k = pi[k - 1];
        }
        if sig[i] == sig[k] {
            k += 1;
        }
        pi[i] = k;
    }
    n - pi[n - 1]
}

/// Drive the (hierarchy × policy) matrix from a *recorded* trace
/// instead of a synthetic generator (`trace-replay --sweep
/// <hier>/<policy> ...`): the tier-tagged ingest reads of a v2+
/// hierarchy recording become the access stream.  Traces carry no
/// block identity (only device/bytes/timing), so blocks are
/// recovered by [`epoch_period`] inference over the (device, bytes)
/// signature — exact for epoch-structured recordings, and safely
/// degenerate (one block per event, so no re-reads and nothing to
/// promote) otherwise.  Every pair is validated before the first
/// cell runs, the same contract as [`run`].
pub fn run_trace_cells(
    trace: &Trace,
    cfg: &TierSweepConfig,
    pairs: &[(String, String)],
) -> Result<Vec<TierSweepCell>> {
    for (h, p) in pairs {
        let _ = spec_for(cfg, h)?;
        let _ = policy::by_name(p)?;
    }
    let reads: Vec<&TraceEvent> = trace
        .events
        .iter()
        .filter(|e| {
            e.ok
                && e.tier.is_some()
                && e.class == IoClass::Ingest
                && e.op == EngineOp::Read
        })
        .collect();
    if reads.is_empty() {
        bail!(
            "trace has no tier-tagged ingest reads — hierarchy/policy \
             sweep cells need a v2+ recording of a hierarchy run \
             (e.g. `dlio train --compute model --device hier:<preset> \
             --trace-out FILE`)"
        );
    }
    let sig: Vec<(&str, u64)> = reads
        .iter()
        .map(|e| (e.device.as_str(), e.bytes))
        .collect();
    let period = epoch_period(&sig);
    let mut cells = Vec::new();
    for (hierarchy, pol) in pairs {
        cells.push(run_trace_cell(cfg, hierarchy, pol, &reads, period)?);
    }
    Ok(cells)
}

/// One trace-driven cell: home the inferred blocks (recorded byte
/// sizes) on the cell hierarchy's bottom tier, then re-issue the
/// recorded read stream through it under the cell's placement
/// policy and snapshot the same columns as the synthetic cells.
fn run_trace_cell(
    cfg: &TierSweepConfig,
    hierarchy: &str,
    pol: &str,
    reads: &[&TraceEvent],
    period: usize,
) -> Result<TierSweepCell> {
    let spec = spec_for(cfg, hierarchy)?;
    let dir = std::path::Path::new(&cfg.workdir)
        .join(format!("tier-sweep-{hierarchy}-{pol}-trace"));
    let _ = std::fs::remove_dir_all(&dir);
    let tb = Testbed::paper(cfg.time_scale);
    let sim = Arc::new(StorageSim::cold_with_qos_clock(
        dir,
        tb.devices,
        crate::storage::QosConfig::default(),
        cfg.clock.build(),
    )?);
    let tiers = spec.tiers.len();
    let bottom = bottom_device_tier(&spec);
    let hier = Arc::new(StorageHierarchy::new(
        Arc::clone(&sim),
        spec,
        policy::by_name(pol)?,
    )?);
    let mut cell = TierSweepCell {
        hierarchy: hierarchy.to_string(),
        policy: hier.policy_name().to_string(),
        workload: "trace".to_string(),
        tiers,
        ops: 0,
        elapsed_secs: 0.0,
        ops_per_sec: 0.0,
        t0_hits: 0,
        t0_hit_frac: 0.0,
        promotions: 0,
        demotions: 0,
        drained: 0,
        ingest_p99_ms: 0.0,
        save_p50_secs: 0.0,
        save_total_secs: 0.0,
        theta: 0.0,
        migration_mb: 0.0,
        predicted_migration_secs: 0.0,
        cost_accuracy: 0.0,
        rejected_by_cost: 0,
        tier_rows: Vec::new(),
    };

    let bottom_dev = hier.device_of(bottom)?;
    let clock = sim.clock().clone();
    let _reg = clock.enter();
    // Fixture: one block per first-epoch read, recorded sizes.
    for (i, e) in reads.iter().take(period).enumerate() {
        let key = format!("blk/{i}.bin");
        let bytes = e.bytes.max(1) as usize;
        let p = SimPath::new(bottom_dev.clone(), key.clone());
        sim.write(&p, &vec![(i % 251) as u8; bytes])?;
        hier.register(&key, bytes as u64, bottom)?;
    }
    sim.drop_caches();
    sim.engine().reset_stats();
    let predicted0 = hier.predicted_migration_secs();
    let t0 = clock.now();
    for i in 0..reads.len() {
        let key = format!("blk/{}.bin", i % period);
        hier.read(&key)
            .context("trace-driven tier-sweep read failed")?;
    }
    cell.ops = reads.len() as u64;
    cell.elapsed_secs = clock.now() - t0;
    cell.predicted_migration_secs =
        hier.predicted_migration_secs() - predicted0;
    snapshot_cell(&sim, &hier, bottom, &mut cell);
    Ok(cell)
}

/// Skewed ingest: `hot_frac` of `reads` accesses cycle through the
/// first `hot_files` files, the rest through the cold tail, in a
/// deterministic interleave.
fn run_hot(
    cfg: &TierSweepConfig,
    sim: &Arc<StorageSim>,
    hier: &Arc<StorageHierarchy>,
    bottom: usize,
    cell: &mut TierSweepCell,
) -> Result<()> {
    let bottom_dev = hier.device_of(bottom)?;
    // Register the driver with the sim's clock for the whole cell:
    // virtual time advances only while we block on tickets.
    let clock = sim.clock().clone();
    let _reg = clock.enter();
    let files = cfg.files.max(2);
    let hot_n = cfg.hot_files.clamp(1, files - 1);
    // Fixture: corpus homed on the bottom tier.
    let mut samples = Vec::with_capacity(files);
    for i in 0..files {
        let key = format!("corpus/f{i}.bin");
        let p = SimPath::new(bottom_dev.clone(), key.clone());
        sim.write(&p, &vec![(i % 251) as u8; cfg.file_bytes])?;
        hier.register(&key, cfg.file_bytes as u64, bottom)?;
        samples.push(Sample {
            path: SimPath::new(bottom_dev.clone(), key),
            label: i as u32,
        });
    }
    sim.drop_caches();

    // Access stream: a deterministic integer error-diffusion
    // interleave (millionths) that realizes `hot_frac` exactly for
    // any CLI-typed fraction — `--hot-frac 0.84` runs 84%, not a
    // tenth-quantized 80%.  A slot is hot when the accumulator
    // crosses 1.
    let step = (cfg.hot_frac * 1e6).round() as u64;
    let total = cfg.warmup_reads + cfg.reads;
    let mut accesses = Vec::with_capacity(total);
    let (mut hi, mut ci) = (0usize, 0usize);
    let mut acc = 0u64;
    for _ in 0..total {
        acc += step;
        if acc >= 1_000_000 {
            acc -= 1_000_000;
            accesses.push(samples[hi % hot_n].clone());
            hi += 1;
        } else {
            accesses.push(samples[hot_n + ci % (files - hot_n)].clone());
            ci += 1;
        }
    }
    let measured = accesses.split_off(cfg.warmup_reads);

    // Warm-up (unmeasured): run the same skew and let any pending
    // promotions land, so the measured phase sees the converged
    // placement.
    if !accesses.is_empty() {
        let mut ds = sharded_reader_hier(
            accesses,
            Arc::clone(hier),
            cfg.shards,
            cfg.window,
        );
        while let Some(item) = ds.next() {
            item.context("tier-sweep warm-up read failed")?;
        }
        hier.wait_idle();
    }
    sim.engine().reset_stats();
    let predicted0 = hier.predicted_migration_secs();

    let t0 = clock.now();
    let mut ds = sharded_reader_hier(
        measured,
        Arc::clone(hier),
        cfg.shards,
        cfg.window,
    );
    let mut n = 0u64;
    while let Some(item) = ds.next() {
        item.context("tier-sweep hot read failed")?;
        n += 1;
    }
    cell.ops = n;
    cell.elapsed_secs = clock.now() - t0;
    cell.predicted_migration_secs =
        hier.predicted_migration_secs() - predicted0;
    Ok(())
}

/// Seed of every mix stream: fixed, so all cells of a sweep see the
/// same access sequence (policies compared on identical inputs) and
/// virtual-clock runs replay bit-for-bit.
const MIX_SEED: u64 = 0xd110_5eed;

/// Zipf/uniform read-write mix over a corpus homed on the bottom
/// tier ([`mixed_accesses`]): reads go through the
/// hierarchy window-deep, writes update the durable home (dropping
/// any promoted copy — the invalidation churn a cost model has to
/// price against).
fn run_mix(
    cfg: &TierSweepConfig,
    sim: &Arc<StorageSim>,
    hier: &Arc<StorageHierarchy>,
    bottom: usize,
    theta: f64,
    cell: &mut TierSweepCell,
) -> Result<()> {
    let bottom_dev = hier.device_of(bottom)?;
    let clock = sim.clock().clone();
    let _reg = clock.enter();
    let files = cfg.files.max(2);
    let mut samples = Vec::with_capacity(files);
    for i in 0..files {
        let key = format!("corpus/f{i}.bin");
        let p = SimPath::new(bottom_dev.clone(), key.clone());
        sim.write(&p, &vec![(i % 251) as u8; cfg.file_bytes])?;
        hier.register(&key, cfg.file_bytes as u64, bottom)?;
        samples.push(Sample {
            path: SimPath::new(bottom_dev.clone(), key),
            label: i as u32,
        });
    }
    sim.drop_caches();

    let total = cfg.warmup_reads + cfg.reads;
    let ops = mixed_accesses(files, total, theta, cfg.rw_ratio, MIX_SEED);
    let (warm, measured) = ops.split_at(cfg.warmup_reads.min(ops.len()));
    if !warm.is_empty() {
        drive_mix(cfg, sim, hier, bottom, &samples, warm)?;
        hier.wait_idle();
    }
    sim.engine().reset_stats();
    let predicted0 = hier.predicted_migration_secs();

    let t0 = clock.now();
    cell.ops = drive_mix(cfg, sim, hier, bottom, &samples, measured)?;
    cell.elapsed_secs = clock.now() - t0;
    cell.predicted_migration_secs =
        hier.predicted_migration_secs() - predicted0;
    Ok(())
}

/// Issue one span of mix ops: consecutive reads batch into a
/// window-deep sharded reader (queue pressure like the `hot`
/// workload), each write flushes the batch first so the
/// read-after-write order of the stream is preserved.
fn drive_mix(
    cfg: &TierSweepConfig,
    sim: &Arc<StorageSim>,
    hier: &Arc<StorageHierarchy>,
    bottom: usize,
    samples: &[Sample],
    ops: &[MixOp],
) -> Result<u64> {
    let bottom_dev = hier.device_of(bottom)?;
    let clock = sim.clock().clone();
    let gap = if cfg.arrival_us > 0.0 && cfg.time_scale > 0.0 {
        cfg.arrival_us * 1e-6 / cfg.time_scale
    } else {
        0.0
    };
    let depth = (cfg.shards * cfg.window).max(1);
    let mut pending: Vec<Sample> = Vec::new();
    let mut n = 0u64;
    let flush = |pending: &mut Vec<Sample>| -> Result<u64> {
        if pending.is_empty() {
            return Ok(0);
        }
        let batch = std::mem::take(pending);
        let mut done = 0u64;
        let mut ds = sharded_reader_hier(
            batch,
            Arc::clone(hier),
            cfg.shards,
            cfg.window,
        );
        while let Some(item) = ds.next() {
            item.context("tier-sweep mix read failed")?;
            done += 1;
        }
        Ok(done)
    };
    for op in ops {
        if gap > 0.0 {
            clock.sleep_secs(gap);
        }
        match *op {
            MixOp::Read(i) => {
                pending.push(samples[i].clone());
                if pending.len() >= depth {
                    n += flush(&mut pending)?;
                }
            }
            MixOp::Write(i) => {
                n += flush(&mut pending)?;
                let key = format!("corpus/f{i}.bin");
                let p = SimPath::new(bottom_dev.clone(), key.clone());
                sim.write_class(
                    &p,
                    &vec![(i % 251) as u8; cfg.file_bytes],
                    IoClass::Ingest,
                )?;
                hier.note_written(&[key], bottom)?;
                n += 1;
            }
        }
    }
    n += flush(&mut pending)?;
    Ok(n)
}

/// Checkpoint saves routed through the hierarchy: the placement
/// policy lands triples on tier 0; write-through presets drain them
/// down in the background — the save pause is the fast tier only.
fn run_ckpt(
    cfg: &TierSweepConfig,
    sim: &Arc<StorageSim>,
    hier: &Arc<StorageHierarchy>,
    cell: &mut TierSweepCell,
) -> Result<()> {
    let params = cfg.ckpt_params.max(16);
    let profile = ProfileMeta {
        name: "sweep".into(),
        input_size: 8,
        num_classes: 4,
        num_params: params,
        params: vec![ParamSpec {
            name: "fc1/kernel".into(),
            shape: vec![params],
        }],
    };
    let state = ModelState::init(&profile, 7);
    let mut saver = crate::checkpoint::Saver::new(
        Arc::clone(sim),
        profile,
        &hier.write_placement().1,
        "ckpt/model",
        cfg.ckpt_saves.max(1),
    );
    saver.set_route(Arc::clone(hier));
    saver.sync_on_save = false;
    sim.engine().reset_stats();
    // Save pauses are clock durations (wall or virtual alike).
    let clock = sim.clock().clone();
    let _reg = clock.enter();
    let mut durations = Vec::with_capacity(cfg.ckpt_saves);
    let total0 = clock.now();
    for s in 0..cfg.ckpt_saves.max(1) as u64 {
        let t0 = clock.now();
        saver.save(&state, (s + 1) * 10)?;
        durations.push(clock.now() - t0);
    }
    cell.save_total_secs = clock.now() - total0;
    cell.elapsed_secs = cell.save_total_secs;
    cell.ops = durations.len() as u64;
    cell.save_p50_secs = crate::metrics::median(&mut durations);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg(tag: &str) -> TierSweepConfig {
        let dir = std::env::temp_dir().join(format!(
            "dlio-tier-sweep-test-{tag}-{}",
            std::process::id()
        ));
        TierSweepConfig {
            hierarchies: vec![
                "tegner-lustre+optane".into(),
                "blackdog-direct-hdd".into(),
            ],
            policies: vec!["noop".into(), "freq".into()],
            workloads: vec!["hot".into()],
            files: 10,
            file_bytes: 4 * 1024,
            reads: 50,
            warmup_reads: 0,
            hot_files: 2,
            hot_frac: 0.8,
            shards: 2,
            window: 2,
            tier0_cap: 6 * 4 * 1024,
            theta: 0.9,
            rw_ratio: 0.9,
            arrival_us: 0.0,
            ws_ratio: 3.0,
            ckpt_saves: 2,
            ckpt_params: 1024,
            // Modest acceleration: reads stay slow enough (tens of
            // µs+) that the async migrator visibly interleaves with
            // the access stream — the property the freq test gates.
            time_scale: 8.0,
            workdir: dir.to_string_lossy().into_owned(),
            clock: ClockSpec::Virtual,
        }
    }

    #[test]
    fn sweep_emits_one_row_per_cell_with_sane_fields() {
        let mut cfg = tiny_cfg("rows");
        cfg.workloads = vec!["hot".into(), "ckpt".into()];
        let cells = run(&cfg).unwrap();
        // hot: 2 hierarchies x 2 policies; ckpt: 2 hierarchies x noop.
        assert_eq!(cells.len(), 6);
        for c in &cells {
            match c.workload.as_str() {
                "hot" => {
                    assert_eq!(c.ops, 50, "every access read exactly once");
                    assert!(c.t0_hit_frac >= 0.0 && c.t0_hit_frac <= 1.0);
                    if c.hierarchy == "blackdog-direct-hdd" {
                        // Single tier: everything is a tier-0 hit.
                        assert_eq!(c.t0_hit_frac, 1.0);
                    }
                }
                "ckpt" => {
                    assert_eq!(c.ops, 2);
                    assert!(c.save_p50_secs > 0.0);
                }
                other => panic!("unexpected workload {other}"),
            }
            assert!(c.elapsed_secs > 0.0);
            assert_eq!(c.tier_rows.len(), c.tiers);
        }
        // CSV: header + one line per cell, constant column count.
        let csv = to_csv(&cells);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 7);
        let ncols = lines[0].split(',').count();
        for l in &lines {
            assert_eq!(l.split(',').count(), ncols, "ragged CSV: {l}");
        }
        // JSON round-trips through the in-repo parser with tier rows.
        let parsed = Json::parse(&to_json(&cells)).unwrap();
        match parsed {
            Json::Arr(rows) => {
                assert_eq!(rows.len(), 6);
                for r in rows {
                    assert!(r.get("hierarchy").and_then(Json::as_str).is_some());
                    let tiers = r
                        .get("tier_rows")
                        .and_then(Json::as_arr)
                        .expect("tier_rows array");
                    assert!(!tiers.is_empty());
                }
            }
            other => panic!("expected a JSON array, got {other:?}"),
        }
    }

    #[test]
    fn frequency_beats_noop_on_the_hot_set() {
        // The tentpole's acceptance property at unit scale: on the
        // 2-tier cache hierarchy, the promotion policy must lift the
        // tier-0 hit fraction strictly above noop's (which never
        // promotes, so its only tier-0 hits would be impossible —
        // the corpus is homed below).
        let mut cfg = tiny_cfg("freqwins");
        cfg.hierarchies = vec!["tegner-lustre+optane".into()];
        let cells = run(&cfg).unwrap();
        assert_eq!(cells.len(), 2);
        let noop = cells.iter().find(|c| c.policy == "noop").unwrap();
        let freq = cells.iter().find(|c| c.policy == "freq").unwrap();
        assert_eq!(noop.t0_hit_frac, 0.0, "noop never promotes");
        assert!(
            freq.t0_hit_frac > 0.3,
            "freq hit frac {:.2} did not capture the hot set",
            freq.t0_hit_frac
        );
        assert!(freq.promotions > 0);
    }

    #[test]
    fn unknown_names_fail_fast_listing_presets() {
        let mut cfg = tiny_cfg("badname");
        cfg.hierarchies = vec!["blackdog-floppy".into()];
        let err = run(&cfg).unwrap_err().to_string();
        assert!(
            err.contains("blackdog-bb") && err.contains("tegner"),
            "hierarchy error does not list presets: {err}"
        );
        let mut cfg = tiny_cfg("badpolicy");
        cfg.policies = vec!["banana".into()];
        let err = run(&cfg).unwrap_err().to_string();
        assert!(err.contains("noop"), "policy error lists names: {err}");
        let mut cfg = tiny_cfg("badworkload");
        cfg.workloads = vec!["warp".into()];
        let err = run(&cfg).unwrap_err().to_string();
        assert!(
            err.contains("zipf") && err.contains("uniform"),
            "workload error does not list names: {err}"
        );
    }

    #[test]
    fn unknown_workload_fails_before_any_cell_runs() {
        // Regression: workload names used to be validated lazily
        // inside the matrix loop, so a typo after a valid workload
        // burned the whole first axis before erroring.  The error
        // must now fire before the first cell touches disk.
        let mut cfg = tiny_cfg("lazybug");
        cfg.workloads = vec!["hot".into(), "warp".into()];
        let err = run(&cfg).unwrap_err().to_string();
        assert!(err.contains("warp"), "error names the bad token: {err}");
        let first_cell = std::path::Path::new(&cfg.workdir).join(
            "tier-sweep-tegner-lustre+optane-noop-hot",
        );
        assert!(
            !first_cell.exists(),
            "a cell ran before workload validation"
        );
        // Malformed zipf skews are typos too, not silent defaults.
        let mut cfg = tiny_cfg("badtheta");
        cfg.workloads = vec!["zipf:hotter".into()];
        assert!(run(&cfg).is_err());
        let mut cfg = tiny_cfg("negtheta");
        cfg.workloads = vec!["zipf:-1".into()];
        assert!(run(&cfg).is_err());
    }

    #[test]
    fn zipf_cells_emit_mix_columns_and_replay_bit_for_bit() {
        let mut cfg = tiny_cfg("zipfmix");
        cfg.hierarchies = vec!["tegner-lustre+optane".into()];
        cfg.policies = vec!["freq".into(), "cost".into()];
        cfg.workloads = vec!["zipf:1.1".into(), "uniform".into()];
        let cells = run(&cfg).unwrap();
        assert_eq!(cells.len(), 4);
        for c in &cells {
            // Reads + writes all issued.
            assert_eq!(c.ops, cfg.reads as u64);
            assert!(c.elapsed_secs > 0.0);
            match c.workload.as_str() {
                "zipf:1.1" => assert_eq!(c.theta, 1.1),
                "uniform" => assert_eq!(c.theta, 0.0),
                other => panic!("unexpected workload {other}"),
            }
        }
        // The cost policy prices its migrations: whenever it moved
        // bytes, the accuracy column must be populated and sane.
        let cost_zipf = cells
            .iter()
            .find(|c| c.policy == "cost" && c.workload == "zipf:1.1")
            .unwrap();
        if cost_zipf.promotions > 0 {
            assert!(cost_zipf.predicted_migration_secs > 0.0);
            assert!(cost_zipf.cost_accuracy > 0.0);
        }
        // Virtual-clock cells are bit-deterministic: a re-run of the
        // same config reproduces the CSV byte-for-byte.
        let again = run(&cfg).unwrap();
        assert_eq!(
            to_csv(&cells),
            to_csv(&again),
            "virtual-clock mix cells must replay bit-for-bit"
        );
    }

    #[test]
    fn epoch_period_infers_the_repeating_prefix() {
        assert_eq!(epoch_period(&[1, 2, 3, 1, 2, 3]), 3);
        // Trailing partial epoch still resolves to the full period.
        assert_eq!(epoch_period(&[1, 2, 3, 1, 2]), 3);
        assert_eq!(epoch_period(&[5, 5, 5, 5]), 1);
        // Aperiodic: every event its own block.
        assert_eq!(epoch_period(&[1, 2, 3]), 3);
        assert_eq!(epoch_period::<u32>(&[]), 1);
    }

    fn synthetic_hier_trace(epochs: usize, blocks: u64) -> Trace {
        use crate::trace::{TraceManifest, TRACE_VERSION};
        let mut events = Vec::new();
        let mut seq = 0u64;
        for _ in 0..epochs {
            for i in 0..blocks {
                events.push(TraceEvent {
                    seq,
                    device: "hdd".into(),
                    class: IoClass::Ingest,
                    op: EngineOp::Read,
                    origin: "reader".into(),
                    tier: Some(1),
                    tenant: String::new(),
                    // Distinct size per block, so the (device, bytes)
                    // signature's period is exactly `blocks` and the
                    // inference recovers every block (same-signature
                    // blocks alias harmlessly, but that's not what
                    // this fixture tests).  Six blocks total 21 KB —
                    // under tiny_cfg's 24 KB tier-0 cap, so every
                    // promotion fits without evictions.
                    bytes: 1024 * (1 + i),
                    ok: true,
                    submit_secs: seq as f64 * 1e-3,
                    queue_secs: 0.0,
                    service_secs: 1e-3,
                });
                seq += 1;
            }
        }
        Trace {
            manifest: TraceManifest {
                version: TRACE_VERSION,
                workload: "synthetic hierarchy run".into(),
                qos_mode: "fair".into(),
                qos: None,
                time_scale: 8.0,
                devices: Testbed::paper(8.0).devices,
            },
            events,
            steps: Vec::new(),
        }
    }

    #[test]
    fn trace_cells_replay_recorded_reads_through_the_matrix() {
        let cfg = tiny_cfg("tracecells");
        // 4 epochs over 6 blocks: freq promotes on the 3rd access,
        // so the 4th epoch reads the promoted copies.
        let trace = synthetic_hier_trace(4, 6);
        let pairs = vec![
            ("tegner-lustre+optane".to_string(), "noop".to_string()),
            ("tegner-lustre+optane".to_string(), "freq".to_string()),
        ];
        let cells = run_trace_cells(&trace, &cfg, &pairs).unwrap();
        assert_eq!(cells.len(), 2);
        for c in &cells {
            assert_eq!(c.workload, "trace");
            assert_eq!(c.ops, 24, "every recorded read re-issued");
            assert!(c.elapsed_secs > 0.0);
            assert_eq!(c.tier_rows.len(), c.tiers);
        }
        // Epoch inference recovered 6 blocks, so epochs 2-3 re-read
        // them and the promotion policy has something to act on.
        let noop = cells.iter().find(|c| c.policy == "noop").unwrap();
        let freq = cells.iter().find(|c| c.policy == "freq").unwrap();
        assert_eq!(noop.t0_hit_frac, 0.0, "noop never promotes");
        assert!(
            freq.promotions > 0,
            "re-read blocks were never promoted"
        );
        assert!(freq.t0_hit_frac > noop.t0_hit_frac);
        // The cells render through the same CSV schema.
        let csv = to_csv(&cells);
        assert_eq!(csv.lines().count(), 3);
    }

    #[test]
    fn trace_cells_validate_pairs_and_require_tier_tags() {
        let cfg = tiny_cfg("tracebad");
        let trace = synthetic_hier_trace(2, 4);
        let bad = vec![(
            "tegner-lustre+optane".to_string(),
            "banana".to_string(),
        )];
        let err =
            run_trace_cells(&trace, &cfg, &bad).unwrap_err().to_string();
        assert!(err.contains("noop"), "policy error lists names: {err}");
        let bad = vec![("floppy".to_string(), "noop".to_string())];
        let err =
            run_trace_cells(&trace, &cfg, &bad).unwrap_err().to_string();
        assert!(
            err.contains("blackdog-bb"),
            "hierarchy error lists presets: {err}"
        );
        // A v1-shaped (untiered) trace cannot drive placement cells.
        let mut flat = synthetic_hier_trace(2, 4);
        for e in &mut flat.events {
            e.tier = None;
        }
        let pairs =
            vec![("tegner-lustre+optane".to_string(), "noop".to_string())];
        let err = run_trace_cells(&flat, &cfg, &pairs)
            .unwrap_err()
            .to_string();
        assert!(
            err.contains("tier-tagged"),
            "untiered trace error should point at v2+ recording: {err}"
        );
    }
}
