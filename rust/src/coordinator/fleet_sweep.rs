//! `dlio fleet-sweep` — multi-tenant isolation characterization.
//!
//! The paper characterizes one training job's I/O interference; the
//! shared-cluster regime (many concurrent jobs contending for one
//! storage substrate) is the ROADMAP north-star.  This driver runs N
//! concurrent synthetic tenant jobs — mixed ingest plus periodic
//! checkpoint bursts — against one shared engine/device (the
//! hierarchy's bottleneck tier) under the virtual clock, across a
//! (tenant count × share scheme × scenario) matrix:
//!
//! * schemes: `equal` (every tenant share 1), `weighted` (tenant i
//!   gets share i+1), `blind` (no tenant config — the flat class-keyed
//!   scheduler, the fairness baseline)
//! * scenarios: `uniform` (identical jobs), `noisy` (tenant 0 issues
//!   `noisy_factor`× the ingest load with an open request window),
//!   `churn` (odd tenants depart halfway — work conservation), `storm`
//!   (correlated checkpoint bursts), `restart` (every tenant opens
//!   with a correlated checkpoint-restore read burst — the
//!   restart-storm regime of DESIGN.md §15, reporting per-tenant
//!   time-to-recover)
//!
//! Each cell emits one CSV/JSON row **per tenant** (exact ingest p99
//! from the event stream, not histogram buckets) plus the cell-level
//! Jain fairness index over per-tenant ingest p99 and goodput.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Barrier};

use anyhow::{anyhow, bail, Context, Result};

use crate::config::Testbed;
use crate::storage::engine::DEFAULT_CHUNK;
use crate::storage::{
    with_tenant, Clock, ClockSpec, Device, IoClass, IoEngine, IoRequest,
    IoTicket, NullObserver, QosConfig, TenantId, TenantQos,
};
use crate::trace::MemorySink;
use crate::util::json::{obj, to_string, Json};

/// Valid share schemes, in canonical order (error messages quote it).
pub const SCHEMES: [&str; 3] = ["equal", "weighted", "blind"];
/// Valid scenarios, in canonical order.
pub const SCENARIOS: [&str; 5] =
    ["uniform", "noisy", "churn", "storm", "restart"];

/// Sweep matrix + per-job workload shape.
#[derive(Debug, Clone)]
pub struct FleetSweepConfig {
    /// Shared device profile the fleet contends on.
    pub device: String,
    /// Fleet sizes (one cell axis).
    pub tenant_counts: Vec<usize>,
    /// Share schemes (see [`SCHEMES`]).
    pub schemes: Vec<String>,
    /// Contention scenarios (see [`SCENARIOS`]).
    pub scenarios: Vec<String>,
    /// Ingest probe reads per tenant job.
    pub reads_per_job: usize,
    /// Bytes per ingest read.
    pub read_bytes: u64,
    /// Checkpoint burst every N reads (0 = no checkpoints).
    pub ckpt_every: usize,
    /// Checkpoint writes per burst.
    pub ckpt_writes: usize,
    /// Bytes per checkpoint write.
    pub ckpt_bytes: u64,
    /// Load multiplier for the noisy tenant.
    pub noisy_factor: usize,
    /// Device simulation speed-up.
    pub time_scale: f64,
    /// Time source per cell (virtual: the whole matrix is modelled).
    pub clock: ClockSpec,
}

impl FleetSweepConfig {
    /// Full matrix: 3 schemes × 5 scenarios × fleets of 2 and 4 —
    /// 30 cells, 90 per-tenant rows.
    pub fn standard(time_scale: f64) -> FleetSweepConfig {
        FleetSweepConfig {
            device: "hdd".into(),
            tenant_counts: vec![2, 4],
            schemes: SCHEMES.iter().map(|s| s.to_string()).collect(),
            scenarios: SCENARIOS.iter().map(|s| s.to_string()).collect(),
            reads_per_job: 48,
            read_bytes: 64 * 1024,
            ckpt_every: 16,
            ckpt_writes: 2,
            ckpt_bytes: 1_000_000,
            noisy_factor: 10,
            time_scale,
            clock: ClockSpec::Virtual,
        }
    }

    /// Tiny CI matrix: 2 schemes × 2 scenarios × one fleet of 2 —
    /// 4 cells, 8 rows, seconds of wall time even on a slow host.
    pub fn smoke(time_scale: f64) -> FleetSweepConfig {
        FleetSweepConfig {
            device: "ssd".into(),
            tenant_counts: vec![2],
            schemes: vec!["equal".into(), "blind".into()],
            scenarios: vec!["uniform".into(), "noisy".into()],
            reads_per_job: 12,
            read_bytes: 16 * 1024,
            ckpt_every: 6,
            ckpt_writes: 1,
            ckpt_bytes: 200_000,
            noisy_factor: 4,
            time_scale,
            clock: ClockSpec::Virtual,
        }
    }
}

/// One tenant's slice of one sweep cell.
#[derive(Debug, Clone)]
pub struct FleetSweepRow {
    pub scheme: String,
    pub scenario: String,
    /// Fleet size of the cell this row belongs to.
    pub tenants: usize,
    pub device: String,
    pub tenant: String,
    /// Outer-DRR share this tenant ran under (1 under `blind`).
    pub share: u32,
    pub ingest_completed: u64,
    /// Exact per-tenant ingest p99 queue wait (clock ms, computed from
    /// the sorted event stream — no histogram quantization).
    pub ingest_p99_ms: f64,
    /// Per-tenant ingest goodput over the cell makespan, MB/s.
    pub goodput_mbps: f64,
    pub ckpt_completed: u64,
    /// Clock seconds this tenant spent in its opening restore burst
    /// (the `restart` scenario's time-to-recover; 0 elsewhere).
    pub recovery_secs: f64,
    /// Cell makespan, clock seconds (same value on every row of the
    /// cell).
    pub elapsed_secs: f64,
    /// Jain's fairness index over the cell's per-tenant ingest p99.
    pub jain_p99: f64,
    /// Jain's fairness index over the cell's per-tenant goodput.
    pub jain_goodput: f64,
}

/// Jain's fairness index `(Σx)² / (n·Σx²)`: 1.0 when all tenants see
/// identical values, → 1/n as one tenant dominates.  An all-zero (or
/// empty) vector is perfectly fair by convention.
pub fn jain(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    let sum: f64 = xs.iter().sum();
    let sq: f64 = xs.iter().map(|x| x * x).sum();
    if sq <= 0.0 {
        return 1.0;
    }
    sum * sum / (xs.len() as f64 * sq)
}

/// Exact quantile of an ascending-sorted sample (nearest-rank).
fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).ceil() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// CSV column order — one place, so header and rows cannot drift.
const CSV_COLUMNS: [&str; 14] = [
    "scheme",
    "scenario",
    "tenants",
    "device",
    "tenant",
    "share",
    "ingest_completed",
    "ingest_p99_ms",
    "goodput_mbps",
    "ckpt_completed",
    "recovery_secs",
    "elapsed_secs",
    "jain_p99",
    "jain_goodput",
];

impl FleetSweepRow {
    fn csv_row(&self) -> String {
        [
            self.scheme.clone(),
            self.scenario.clone(),
            self.tenants.to_string(),
            self.device.clone(),
            self.tenant.clone(),
            self.share.to_string(),
            self.ingest_completed.to_string(),
            format!("{:.4}", self.ingest_p99_ms),
            format!("{:.3}", self.goodput_mbps),
            self.ckpt_completed.to_string(),
            format!("{:.6}", self.recovery_secs),
            format!("{:.4}", self.elapsed_secs),
            format!("{:.4}", self.jain_p99),
            format!("{:.4}", self.jain_goodput),
        ]
        .join(",")
    }

    fn json_value(&self) -> Json {
        obj(vec![
            ("scheme", Json::Str(self.scheme.clone())),
            ("scenario", Json::Str(self.scenario.clone())),
            ("tenants", Json::Num(self.tenants as f64)),
            ("device", Json::Str(self.device.clone())),
            ("tenant", Json::Str(self.tenant.clone())),
            ("share", Json::Num(self.share as f64)),
            ("ingest_completed", Json::Num(self.ingest_completed as f64)),
            ("ingest_p99_ms", Json::Num(self.ingest_p99_ms)),
            ("goodput_mbps", Json::Num(self.goodput_mbps)),
            ("ckpt_completed", Json::Num(self.ckpt_completed as f64)),
            ("recovery_secs", Json::Num(self.recovery_secs)),
            ("elapsed_secs", Json::Num(self.elapsed_secs)),
            ("jain_p99", Json::Num(self.jain_p99)),
            ("jain_goodput", Json::Num(self.jain_goodput)),
        ])
    }
}

/// Render rows as CSV (header + one line per tenant per cell).
pub fn to_csv(rows: &[FleetSweepRow]) -> String {
    let mut out = CSV_COLUMNS.join(",");
    out.push('\n');
    for r in rows {
        out.push_str(&r.csv_row());
        out.push('\n');
    }
    out
}

/// Render rows as a JSON array (one object per tenant per cell).
pub fn to_json(rows: &[FleetSweepRow]) -> String {
    to_string(&Json::Arr(rows.iter().map(|r| r.json_value()).collect()))
}

/// One tenant job's plan for a cell (scenario already applied).
#[derive(Debug, Clone)]
struct JobPlan {
    reads: usize,
    /// In-flight ingest window (1 = closed loop; the noisy tenant
    /// keeps an open window — the oversubscription itself).
    window: usize,
    read_bytes: u64,
    ckpt_every: usize,
    ckpt_writes: usize,
    ckpt_bytes: u64,
    /// Checkpoint-restore reads issued as one opening burst before any
    /// ingest (the `restart` scenario; 0 elsewhere).  The burst's
    /// drain time is the tenant's time-to-recover.
    restore_reads: usize,
}

impl JobPlan {
    fn new(cfg: &FleetSweepConfig, scenario: &str, idx: usize) -> JobPlan {
        let mut plan = JobPlan {
            reads: cfg.reads_per_job.max(1),
            window: 1,
            read_bytes: cfg.read_bytes.max(1),
            ckpt_every: cfg.ckpt_every,
            ckpt_writes: cfg.ckpt_writes,
            ckpt_bytes: cfg.ckpt_bytes.max(1),
            restore_reads: 0,
        };
        match scenario {
            "noisy" if idx == 0 => {
                plan.reads *= cfg.noisy_factor.max(1);
                plan.window = 4;
            }
            "churn" if idx % 2 == 1 => {
                // Departing tenants: half the work, then idle.  Work
                // conservation means the survivors absorb the slack.
                plan.reads = (plan.reads / 2).max(1);
            }
            "storm" => {
                // Correlated bursts: every tenant's checkpoint arrives
                // in lockstep, 4× the writes.
                plan.ckpt_writes *= 4;
            }
            "restart" => {
                // Restart storm: every tenant re-reads its checkpoint
                // set at t=0 before ingest resumes — the whole fleet's
                // restores land on the device at once.
                plan.restore_reads = (plan.ckpt_writes * 2).max(2);
            }
            _ => {}
        }
        plan
    }
}

/// Scheduler config for a scheme over `names` (validated upfront).
fn qos_for_scheme(scheme: &str, names: &[String]) -> Result<QosConfig> {
    match scheme {
        // Every tenant (and untagged traffic) at the default share.
        "equal" => Ok(QosConfig::default().with_tenants(TenantQos::default())),
        "weighted" => {
            let mut t = TenantQos::default();
            for (i, name) in names.iter().enumerate() {
                t = t.with_share(name, (i + 1) as u32);
            }
            Ok(QosConfig::default().with_tenants(t))
        }
        // No tenant table: the flat class-keyed scheduler.
        "blind" => Ok(QosConfig::default()),
        other => Err(anyhow!(
            "unknown share scheme {other:?} (valid: {})",
            SCHEMES.join(", ")
        )),
    }
}

/// Device model for the configured profile name, at the sweep's time
/// scale.
fn device_model(cfg: &FleetSweepConfig) -> Result<crate::storage::DeviceModel> {
    Testbed::paper(cfg.time_scale)
        .devices
        .into_iter()
        .find(|m| m.name == cfg.device)
        .ok_or_else(|| anyhow!("unknown device {:?}", cfg.device))
}

/// Run the full matrix; rows come back in (scheme, scenario, fleet
/// size, tenant index) iteration order — `tenants` rows per cell.
pub fn run(cfg: &FleetSweepConfig) -> Result<Vec<FleetSweepRow>> {
    // Validate the whole matrix before running the first cell.
    for s in &cfg.schemes {
        qos_for_scheme(s, &[])?;
    }
    for s in &cfg.scenarios {
        if !SCENARIOS.contains(&s.as_str()) {
            bail!(
                "unknown scenario {s:?} (valid: {})",
                SCENARIOS.join(", ")
            );
        }
    }
    if cfg.tenant_counts.iter().any(|&n| n == 0) {
        bail!("fleet size must be at least 1");
    }
    let mut rows = Vec::new();
    for scheme in &cfg.schemes {
        for scenario in &cfg.scenarios {
            for &n in &cfg.tenant_counts {
                rows.extend(run_cell(cfg, scheme, scenario, n)?);
            }
        }
    }
    Ok(rows)
}

/// Run one tenant job; returns the tenant's recovery time (clock
/// seconds its opening restore burst took; 0 without one).
fn run_one_job(
    engine: &IoEngine,
    device: &str,
    plan: &JobPlan,
    clock: &Clock,
) -> Result<f64> {
    let mut recovery_secs = 0.0;
    if plan.restore_reads > 0 {
        // Correlated restore burst: submit the whole set at once
        // (Checkpoint class — restores are checkpoint traffic, not
        // ingest), then wait it out.  Burst drain time = recovery.
        let t0 = clock.now();
        let restores: Vec<IoTicket> = (0..plan.restore_reads)
            .map(|_| {
                engine.submit_class(
                    IoRequest::ProbeRead {
                        device: device.to_string(),
                        bytes: plan.ckpt_bytes,
                    },
                    IoClass::Checkpoint,
                )
            })
            .collect::<Result<_>>()?;
        for t in restores {
            t.wait().context("fleet restore read failed")?;
        }
        recovery_secs = clock.now() - t0;
    }
    let mut inflight: VecDeque<IoTicket> = VecDeque::new();
    let mut ckpts: Vec<IoTicket> = Vec::new();
    for i in 0..plan.reads {
        while inflight.len() >= plan.window.max(1) {
            inflight
                .pop_front()
                .expect("window is nonempty")
                .wait()
                .context("fleet ingest read failed")?;
        }
        inflight.push_back(engine.submit(IoRequest::ProbeRead {
            device: device.to_string(),
            bytes: plan.read_bytes,
        })?);
        if plan.ckpt_every > 0 && (i + 1) % plan.ckpt_every == 0 {
            for _ in 0..plan.ckpt_writes {
                ckpts.push(engine.submit(IoRequest::ProbeWrite {
                    device: device.to_string(),
                    bytes: plan.ckpt_bytes,
                })?);
            }
        }
    }
    for t in inflight {
        t.wait().context("fleet ingest read failed")?;
    }
    for t in ckpts {
        t.wait().context("fleet checkpoint write failed")?;
    }
    Ok(recovery_secs)
}

fn run_cell(
    cfg: &FleetSweepConfig,
    scheme: &str,
    scenario: &str,
    n: usize,
) -> Result<Vec<FleetSweepRow>> {
    let names: Vec<String> = (0..n).map(|i| format!("t{i}")).collect();
    let qos = qos_for_scheme(scheme, &names)?;
    let shares: Vec<u32> = names
        .iter()
        .map(|name| {
            qos.tenants.as_ref().map_or(1, |t| t.share_for(name))
        })
        .collect();
    let clock = cfg.clock.build();
    let model = device_model(cfg)?;
    let mut devices = HashMap::new();
    devices.insert(
        model.name.clone(),
        Arc::new(Device::with_clock(
            model,
            Arc::new(NullObserver),
            clock.clone(),
        )),
    );
    let engine =
        Arc::new(IoEngine::with_config(&devices, DEFAULT_CHUNK, qos));
    let sink = MemorySink::new();
    engine.set_observer(
        Arc::clone(&sink) as Arc<dyn crate::storage::EngineObserver>
    );

    // Register-then-barrier: every job registers with the clock before
    // any job submits, so virtual time cannot advance while a late
    // thread is still spawning (the clock-test idiom — without it the
    // jobs' start order would depend on the host scheduler).
    let barrier = Arc::new(Barrier::new(n));
    let t0 = clock.now();
    let handles: Vec<_> = names
        .iter()
        .enumerate()
        .map(|(i, name)| {
            let plan = JobPlan::new(cfg, scenario, i);
            let engine = Arc::clone(&engine);
            let clock = clock.clone();
            let barrier = Arc::clone(&barrier);
            let tenant = TenantId::new(name);
            let device = cfg.device.clone();
            std::thread::Builder::new()
                .name(format!("fleet-{name}"))
                .spawn(move || -> Result<f64> {
                    let _reg = clock.enter();
                    barrier.wait();
                    with_tenant(&tenant, || {
                        run_one_job(&engine, &device, &plan, &clock)
                    })
                })
                .context("spawn fleet job")
        })
        .collect::<Result<_>>()?;
    let mut recoveries = Vec::with_capacity(n);
    for h in handles {
        recoveries
            .push(h.join().map_err(|_| anyhow!("fleet job panicked"))??);
    }
    let elapsed = (clock.now() - t0).max(1e-9);
    engine.clear_observer();

    // Per-tenant slices of the event stream: exact p99 from the sorted
    // queue waits (histograms would quantize 2× per log2 bucket).
    let events = sink.events();
    let mut rows = Vec::with_capacity(n);
    let mut p99s = Vec::with_capacity(n);
    let mut goodputs = Vec::with_capacity(n);
    for (i, name) in names.iter().enumerate() {
        let mut queues: Vec<f64> = Vec::new();
        let mut bytes = 0u64;
        let mut completed = 0u64;
        let mut ckpt = 0u64;
        for e in events.iter().filter(|e| &e.tenant == name) {
            match e.class {
                IoClass::Ingest => {
                    completed += 1;
                    bytes += e.bytes;
                    queues.push(e.queue_secs);
                }
                IoClass::Checkpoint => ckpt += 1,
                _ => {}
            }
        }
        queues.sort_by(f64::total_cmp);
        let p99 = percentile(&queues, 0.99);
        let goodput = bytes as f64 / elapsed / 1e6;
        p99s.push(p99);
        goodputs.push(goodput);
        rows.push(FleetSweepRow {
            scheme: scheme.to_string(),
            scenario: scenario.to_string(),
            tenants: n,
            device: cfg.device.clone(),
            tenant: name.clone(),
            share: shares[i],
            ingest_completed: completed,
            ingest_p99_ms: p99 * 1e3,
            goodput_mbps: goodput,
            ckpt_completed: ckpt,
            recovery_secs: recoveries[i],
            elapsed_secs: elapsed,
            jain_p99: 0.0,
            jain_goodput: 0.0,
        });
    }
    let (jp, jg) = (jain(&p99s), jain(&goodputs));
    for r in &mut rows {
        r.jain_p99 = jp;
        r.jain_goodput = jg;
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> FleetSweepConfig {
        let mut cfg = FleetSweepConfig::smoke(1000.0);
        cfg.reads_per_job = 8;
        cfg.ckpt_every = 4;
        cfg
    }

    #[test]
    fn jain_index_brackets() {
        assert!((jain(&[]) - 1.0).abs() < 1e-12);
        assert!((jain(&[0.0, 0.0]) - 1.0).abs() < 1e-12);
        assert!((jain(&[3.0, 3.0, 3.0]) - 1.0).abs() < 1e-12);
        // One tenant hogging everything → 1/n.
        assert!((jain(&[1.0, 0.0, 0.0, 0.0]) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn sweep_emits_one_row_per_tenant_per_cell() {
        let cfg = tiny_cfg();
        let rows = run(&cfg).unwrap();
        // 2 schemes × 2 scenarios × fleet of 2 = 4 cells, 8 rows.
        assert_eq!(rows.len(), 8);
        for r in &rows {
            assert!(r.elapsed_secs > 0.0);
            assert!(r.jain_p99 > 0.0 && r.jain_p99 <= 1.0 + 1e-9);
            assert!(r.jain_goodput > 0.0 && r.jain_goodput <= 1.0 + 1e-9);
            let expected = if r.scenario == "noisy" && r.tenant == "t0" {
                cfg.reads_per_job as u64 * cfg.noisy_factor as u64
            } else {
                cfg.reads_per_job as u64
            };
            assert_eq!(
                r.ingest_completed, expected,
                "{}/{}/{}: every submitted read completes",
                r.scheme, r.scenario, r.tenant
            );
            // reads_per_job 8 / ckpt_every 4 = 2 bursts × 1 write.
            if !(r.scenario == "noisy" && r.tenant == "t0") {
                assert_eq!(r.ckpt_completed, 2);
            }
            // No restore burst outside the restart scenario.
            assert_eq!(r.recovery_secs, 0.0, "{}: phantom recovery",
                       r.scenario);
        }
        // Identical jobs under equal shares: goodput is near-even.
        let uniform = rows
            .iter()
            .find(|r| r.scheme == "equal" && r.scenario == "uniform")
            .unwrap();
        assert!(
            uniform.jain_goodput > 0.8,
            "equal/uniform jain_goodput {}",
            uniform.jain_goodput
        );
        // CSV: header + one line per row, constant column count.
        let csv = to_csv(&rows);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 9);
        let ncols = lines[0].split(',').count();
        assert_eq!(ncols, CSV_COLUMNS.len());
        for l in &lines {
            assert_eq!(l.split(',').count(), ncols, "ragged CSV: {l}");
        }
        // JSON round-trips through the in-repo parser.
        let parsed = Json::parse(&to_json(&rows)).unwrap();
        match parsed {
            Json::Arr(objs) => {
                assert_eq!(objs.len(), 8);
                for o in objs {
                    assert!(o.get("tenant").and_then(Json::as_str).is_some());
                    assert!(o.get("jain_goodput").is_some());
                }
            }
            other => panic!("expected a JSON array, got {other:?}"),
        }
    }

    #[test]
    fn restart_storm_reports_per_tenant_recovery() {
        // DESIGN.md §15: the whole fleet restores at t=0; every tenant
        // reports how long its correlated restore burst took before
        // ingest resumed.
        let mut cfg = tiny_cfg();
        cfg.schemes = vec!["equal".into()];
        cfg.scenarios = vec!["restart".into()];
        let rows = run(&cfg).unwrap();
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert!(
                r.recovery_secs > 0.0,
                "{}: restart tenant reports no recovery time",
                r.tenant
            );
            assert!(r.recovery_secs <= r.elapsed_secs + 1e-9);
            // restore burst (2 × ckpt_writes, min 2) + the regular
            // bursts (reads 8 / every 4 × 1 write) — all Checkpoint
            // class.
            assert_eq!(r.ckpt_completed, 4);
            assert_eq!(r.ingest_completed, cfg.reads_per_job as u64);
            assert!(r.jain_goodput > 0.0 && r.jain_goodput <= 1.0 + 1e-9);
        }
    }

    #[test]
    fn unknown_scheme_and_scenario_are_rejected_with_valid_names() {
        let mut cfg = tiny_cfg();
        cfg.schemes = vec!["banana".into()];
        let err = run(&cfg).unwrap_err().to_string();
        assert!(
            err.contains("equal") && err.contains("blind"),
            "scheme error does not list valid names: {err}"
        );
        let mut cfg = tiny_cfg();
        cfg.scenarios = vec!["quiet".into()];
        let err = run(&cfg).unwrap_err().to_string();
        assert!(
            err.contains("uniform") && err.contains("storm"),
            "scenario error does not list valid names: {err}"
        );
    }
}
