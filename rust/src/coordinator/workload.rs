//! The map functions of the paper's pipelines (§III-A, §III-B): the
//! per-element work that `parallel_map` fans out over
//! `num_parallel_calls` threads.
//!
//! * [`read_only_fn`] — just `tf.read()` (Fig. 5's stripped pipeline).
//! * [`preprocess_fn`] — `tf.read()` + decode + the fused Pallas
//!   normalize/resize kernel via the AOT preprocess executable
//!   (Figs. 4 & 6's full pipeline).

use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use crate::data::format;
use crate::data::manifest::Sample;
use crate::pipeline::{LoadedSample, ProcessedImage};
use crate::runtime::executable::{lit, ExecSpec, Executable};
use crate::runtime::Runtime;
use crate::storage::StorageSim;

/// Raw element for the read-only pipeline: bytes + provenance.
pub struct RawFile {
    pub bytes: Vec<u8>,
    pub label: u32,
}

/// Fig. 5 map function: read the file, nothing else.
pub fn read_only_fn(
    sim: Arc<StorageSim>,
) -> impl Fn(Sample) -> Result<RawFile> + Send + Sync {
    move |sample: Sample| {
        let bytes = sim.read(&sample.path)?;
        Ok(RawFile { bytes, label: sample.label })
    }
}

/// Decode + fused normalize/resize on already-fetched bytes: the
/// compute half shared by [`preprocess_fn`] (which also reads) and
/// [`preprocess_loaded_fn`] (fed by the engine readahead source).
fn process_bytes(
    spec: &ExecSpec,
    sample: &Sample,
    bytes: &[u8],
    src_size: usize,
    out_size: usize,
) -> Result<ProcessedImage> {
    let exe = spec.get()?; // per-thread compile cache
    let img = format::decode(bytes)
        .with_context(|| format!("decoding {}", sample.path))?;
    if img.width as usize != src_size || img.height as usize != src_size {
        return Err(anyhow!(
            "{}: geometry {}x{} outside the {src_size} bucket",
            sample.path, img.width, img.height
        ));
    }
    let pixels = run_preprocess(&exe, &img.pixels, src_size, out_size)?;
    Ok(ProcessedImage {
        pixels,
        size: out_size as u32,
        label: sample.label,
        bytes_read: bytes.len() as u64,
    })
}

/// Figs. 4/6 map function: read -> decode (DEFLATE, the JPEG-decode
/// stand-in) -> fused normalize+resize via the L1 Pallas kernel
/// (executed through PJRT).
pub fn preprocess_fn(
    sim: Arc<StorageSim>,
    rt: &Runtime,
    src_size: usize,
    out_size: usize,
) -> Result<impl Fn(Sample) -> Result<ProcessedImage> + Send + Sync> {
    let spec: ExecSpec = rt.preprocess(src_size, out_size)?;
    Ok(move |sample: Sample| {
        let bytes = sim.read(&sample.path)?;
        process_bytes(&spec, &sample, &bytes, src_size, out_size)
    })
}

/// Readahead variant of [`preprocess_fn`]: the engine already fetched
/// the bytes (`source::read_ahead`), the map workers only decode and
/// resize.
pub fn preprocess_loaded_fn(
    rt: &Runtime,
    src_size: usize,
    out_size: usize,
) -> Result<impl Fn(LoadedSample) -> Result<ProcessedImage> + Send + Sync> {
    let spec: ExecSpec = rt.preprocess(src_size, out_size)?;
    Ok(move |loaded: LoadedSample| {
        process_bytes(&spec, &loaded.sample, &loaded.bytes, src_size, out_size)
    })
}

/// Execute the preprocess HLO on one image's raw pixels.
pub fn run_preprocess(
    exe: &Executable,
    raw: &[u8],
    src_size: usize,
    out_size: usize,
) -> Result<Vec<f32>> {
    let input = lit::u8(&[1, src_size, src_size, 3], raw)?;
    let mut out = exe.run(&[input])?;
    if out.len() != 1 {
        return Err(anyhow!("preprocess returned {} outputs", out.len()));
    }
    let result = lit::to_f32(&out.pop().unwrap())?;
    let want = out_size * out_size * 3;
    if result.len() != want {
        return Err(anyhow!("preprocess produced {} values, want {want}",
                           result.len()));
    }
    Ok(result)
}
