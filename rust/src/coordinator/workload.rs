//! The map functions of the paper's pipelines (§III-A, §III-B): the
//! per-element work that `parallel_map` fans out over
//! `num_parallel_calls` threads — plus the skewed access-stream
//! generators ([`ZipfSampler`], [`mixed_accesses`]) that drive the
//! tier-sweep's read-write-mix workloads.
//!
//! * [`read_only_fn`] — just `tf.read()` (Fig. 5's stripped pipeline).
//! * [`preprocess_fn`] — `tf.read()` + decode + the fused Pallas
//!   normalize/resize kernel via the AOT preprocess executable
//!   (Figs. 4 & 6's full pipeline).

use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use crate::data::format;
use crate::data::manifest::Sample;
use crate::pipeline::{LoadedSample, ProcessedImage};
use crate::runtime::executable::{lit, ExecSpec, Executable};
use crate::runtime::Runtime;
use crate::storage::StorageSim;
use crate::util::Rng;

/// One op of a read-write-mix access stream ([`mixed_accesses`]):
/// the payload is a rank into the generator's corpus (rank 0 is the
/// hottest file under skew).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MixOp {
    Read(usize),
    Write(usize),
}

/// Zipf(theta) rank sampler over `n` items: rank `i` carries weight
/// `1/(i+1)^theta`, so `theta = 0` degenerates to uniform and larger
/// theta concentrates mass on the low ranks.  The CDF is precomputed
/// once and each draw is a binary search; randomness comes from the
/// caller's seeded xoshiro stream, so a `(seed, n, theta)` triple
/// always yields the same sequence — the bit-determinism the
/// virtual-clock sweep cells rely on.
pub struct ZipfSampler {
    cdf: Vec<f64>,
}

impl ZipfSampler {
    pub fn new(n: usize, theta: f64) -> ZipfSampler {
        let n = n.max(1);
        let mut cdf = Vec::with_capacity(n);
        let mut total = 0.0;
        for i in 0..n {
            total += 1.0 / ((i + 1) as f64).powf(theta);
            cdf.push(total);
        }
        for c in &mut cdf {
            *c /= total;
        }
        ZipfSampler { cdf }
    }

    /// Draw one rank in `[0, n)`.
    pub fn draw(&self, rng: &mut Rng) -> usize {
        let u = rng.next_f64();
        self.cdf
            .partition_point(|&c| c <= u)
            .min(self.cdf.len() - 1)
    }
}

/// Deterministic Zipf/uniform read-write-mix stream over an `n`-file
/// corpus: every slot draws a rank from [`ZipfSampler::new`]`(n,
/// theta)` and is a read with probability `rw_ratio` (`1.0` =
/// read-only).  Writes model in-place updates of the drawn file —
/// under a tiered hierarchy they invalidate any promoted copy, which
/// is exactly the churn the cost-aware placement study measures.
pub fn mixed_accesses(
    n: usize,
    ops: usize,
    theta: f64,
    rw_ratio: f64,
    seed: u64,
) -> Vec<MixOp> {
    let z = ZipfSampler::new(n, theta);
    let mut rng = Rng::new(seed);
    (0..ops)
        .map(|_| {
            let i = z.draw(&mut rng);
            if rng.next_f64() < rw_ratio {
                MixOp::Read(i)
            } else {
                MixOp::Write(i)
            }
        })
        .collect()
}

/// Raw element for the read-only pipeline: bytes + provenance.
pub struct RawFile {
    pub bytes: Vec<u8>,
    pub label: u32,
}

/// Fig. 5 map function: read the file, nothing else.
pub fn read_only_fn(
    sim: Arc<StorageSim>,
) -> impl Fn(Sample) -> Result<RawFile> + Send + Sync {
    move |sample: Sample| {
        let bytes = sim.read(&sample.path)?;
        Ok(RawFile { bytes, label: sample.label })
    }
}

/// Decode + fused normalize/resize on already-fetched bytes: the
/// compute half shared by [`preprocess_fn`] (which also reads) and
/// [`preprocess_loaded_fn`] (fed by the engine readahead source).
fn process_bytes(
    spec: &ExecSpec,
    sample: &Sample,
    bytes: &[u8],
    src_size: usize,
    out_size: usize,
) -> Result<ProcessedImage> {
    let exe = spec.get()?; // per-thread compile cache
    let img = format::decode(bytes)
        .with_context(|| format!("decoding {}", sample.path))?;
    if img.width as usize != src_size || img.height as usize != src_size {
        return Err(anyhow!(
            "{}: geometry {}x{} outside the {src_size} bucket",
            sample.path, img.width, img.height
        ));
    }
    let pixels = run_preprocess(&exe, &img.pixels, src_size, out_size)?;
    Ok(ProcessedImage {
        pixels,
        size: out_size as u32,
        label: sample.label,
        bytes_read: bytes.len() as u64,
    })
}

/// Figs. 4/6 map function: read -> decode (DEFLATE, the JPEG-decode
/// stand-in) -> fused normalize+resize via the L1 Pallas kernel
/// (executed through PJRT).
pub fn preprocess_fn(
    sim: Arc<StorageSim>,
    rt: &Runtime,
    src_size: usize,
    out_size: usize,
) -> Result<impl Fn(Sample) -> Result<ProcessedImage> + Send + Sync> {
    let spec: ExecSpec = rt.preprocess(src_size, out_size)?;
    Ok(move |sample: Sample| {
        let bytes = sim.read(&sample.path)?;
        process_bytes(&spec, &sample, &bytes, src_size, out_size)
    })
}

/// Readahead variant of [`preprocess_fn`]: the engine already fetched
/// the bytes (`source::read_ahead`), the map workers only decode and
/// resize.
pub fn preprocess_loaded_fn(
    rt: &Runtime,
    src_size: usize,
    out_size: usize,
) -> Result<impl Fn(LoadedSample) -> Result<ProcessedImage> + Send + Sync> {
    let spec: ExecSpec = rt.preprocess(src_size, out_size)?;
    Ok(move |loaded: LoadedSample| {
        process_bytes(&spec, &loaded.sample, &loaded.bytes, src_size, out_size)
    })
}

/// Execute the preprocess HLO on one image's raw pixels.
pub fn run_preprocess(
    exe: &Executable,
    raw: &[u8],
    src_size: usize,
    out_size: usize,
) -> Result<Vec<f32>> {
    let input = lit::u8(&[1, src_size, src_size, 3], raw)?;
    let mut out = exe.run(&[input])?;
    if out.len() != 1 {
        return Err(anyhow!("preprocess returned {} outputs", out.len()));
    }
    let result = lit::to_f32(&out.pop().unwrap())?;
    let want = out_size * out_size * 3;
    if result.len() != want {
        return Err(anyhow!("preprocess produced {} values, want {want}",
                           result.len()));
    }
    Ok(result)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rank_counts(ops: &[MixOp], n: usize) -> Vec<usize> {
        let mut counts = vec![0usize; n];
        for op in ops {
            let (MixOp::Read(i) | MixOp::Write(i)) = *op;
            counts[i] += 1;
        }
        counts
    }

    #[test]
    fn zipf_stream_is_bit_deterministic_per_seed() {
        let a = mixed_accesses(64, 500, 0.9, 0.8, 7);
        let b = mixed_accesses(64, 500, 0.9, 0.8, 7);
        assert_eq!(a, b, "same (seed, n, theta) must replay exactly");
        let c = mixed_accesses(64, 500, 0.9, 0.8, 8);
        assert_ne!(a, c, "a different seed must decorrelate the stream");
    }

    #[test]
    fn zipf_skew_concentrates_on_low_ranks() {
        let n = 64;
        let ops = mixed_accesses(n, 4000, 1.2, 1.0, 11);
        let counts = rank_counts(&ops, n);
        // Under theta=1.2 the head rank takes a large multiple of the
        // uniform share (1/64 of 4000 ≈ 62); the deep tail is rare.
        assert!(
            counts[0] > 4 * (4000 / n),
            "rank 0 drew only {} of 4000",
            counts[0]
        );
        assert!(counts[0] > counts[n / 2] && counts[0] > counts[n - 1]);
        let tail: usize = counts[n / 2..].iter().sum();
        assert!(
            tail < 4000 / 4,
            "tail half drew {tail} of 4000 — not skewed"
        );
    }

    #[test]
    fn theta_zero_is_uniform_and_rw_ratio_splits_ops() {
        let n = 16;
        let ops = mixed_accesses(n, 4000, 0.0, 0.75, 3);
        let counts = rank_counts(&ops, n);
        // Every rank near the uniform share (250 ± 40%).
        for (i, &c) in counts.iter().enumerate() {
            assert!(
                c > 150 && c < 350,
                "rank {i} drew {c}, far from uniform 250"
            );
        }
        let writes =
            ops.iter().filter(|o| matches!(o, MixOp::Write(_))).count();
        let frac = writes as f64 / ops.len() as f64;
        assert!(
            (frac - 0.25).abs() < 0.05,
            "write fraction {frac:.3}, want ~0.25"
        );
    }

    #[test]
    fn sampler_clamps_edge_draws_into_range() {
        let z = ZipfSampler::new(1, 2.0);
        let mut rng = Rng::new(0);
        for _ in 0..100 {
            assert_eq!(z.draw(&mut rng), 0);
        }
        let z = ZipfSampler::new(5, 0.9);
        let mut rng = Rng::new(9);
        for _ in 0..2000 {
            assert!(z.draw(&mut rng) < 5);
        }
    }
}
