//! The AlexNet mini-application (§III-B): input pipeline + training.
//!
//! Pipeline: manifest -> shuffle -> parallel map (read + decode + fused
//! resize) -> ignore_errors -> batch -> assemble -> prefetch(0|1) ->
//! train step (AOT AlexNet fwd/bwd/Adam via PJRT).  Regenerates
//! Figs. 6, 7 and 8 and carries the checkpoint study (Figs. 9, 10).

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::checkpoint::{BurstBuffer, Saver};
use crate::compute::StepRecord;
use crate::config::{
    CheckpointTarget, CkptStudyConfig, MiniAppConfig, DEFAULT_SHARD_WINDOW,
};
use crate::data::manifest::Manifest;
use crate::metrics::Timer;
use crate::model::Trainer;
use crate::pipeline::{
    collect, from_manifest, sharded_reader_hier, Dataset, DatasetExt,
    ImageBatch,
};
use crate::runtime::{ProfileMeta, Runtime};
use crate::storage::{StorageHierarchy, StorageSim};
use crate::util::Rng;

use super::workload::{preprocess_fn, preprocess_loaded_fn};

/// Outcome of one mini-app run.
#[derive(Debug, Clone)]
pub struct MiniAppResult {
    pub steps: u64,
    pub images: u64,
    pub total_secs: f64,
    /// Time the training loop spent blocked waiting on the iterator —
    /// the visible I/O cost (≈0 when prefetch fully overlaps, §V-B).
    pub ingest_wait_secs: f64,
    /// Time inside the train-step executable.
    pub compute_secs: f64,
    /// Time paused inside checkpoint saves (0 without checkpointing).
    pub ckpt_secs: f64,
    /// Per-checkpoint durations.
    pub ckpt_durations: Vec<f64>,
    pub losses: Vec<f32>,
    /// Per-step phase breakdown (schema-v4 trace lines via
    /// `--trace-out`).
    pub step_records: Vec<StepRecord>,
}

/// Assemble the full mini-app input pipeline for `cfg`, ending after
/// prefetch.  Returned dataset yields ready [`ImageBatch`]es.
pub fn input_pipeline(
    sim: Arc<StorageSim>,
    rt: &Runtime,
    manifest: &Manifest,
    cfg: &MiniAppConfig,
) -> Result<crate::pipeline::prefetch::Prefetch<ImageBatch>> {
    let prof = rt.meta().profile(&cfg.profile)?;
    let out_size = prof.input_size;
    let num_classes = manifest.num_classes;
    let f = preprocess_fn(
        Arc::clone(&sim),
        rt,
        manifest.src_size as usize,
        out_size,
    )?;
    let ds = from_manifest(manifest)
        .shuffle(manifest.len().max(1), Rng::new(cfg.seed))
        .parallel_map(cfg.threads, f)
        .ignore_errors()
        // drop_remainder: the train HLO is shape-specialized (§IV-B
        // runs 142 full batches for the same reason).
        .batch(cfg.batch, true)
        // Batch assembly happens on the pipeline side so prefetch
        // hands the trainer a ready tensor.
        .parallel_map(1, move |samples| {
            ImageBatch::assemble(samples, num_classes)
        })
        .prefetch(cfg.prefetch);
    Ok(ds)
}

/// Hierarchy-routed variant of [`input_pipeline`]: file reads go
/// through a storage hierarchy via the engine-backed sharded source
/// (whichever tier holds a sample serves it, and the placement policy
/// sees every access), then decode/assemble/prefetch as usual.
pub fn input_pipeline_hier(
    hier: Arc<StorageHierarchy>,
    rt: &Runtime,
    manifest: &Manifest,
    cfg: &MiniAppConfig,
) -> Result<crate::pipeline::prefetch::Prefetch<ImageBatch>> {
    let prof = rt.meta().profile(&cfg.profile)?;
    let num_classes = manifest.num_classes;
    let f = preprocess_loaded_fn(
        rt,
        manifest.src_size as usize,
        prof.input_size,
    )?;
    // The shuffle buffer covers the whole list, so materializing the
    // shuffled order up front is semantics-preserving (the sharded
    // source needs a concrete sample list).
    let samples = collect(
        from_manifest(manifest)
            .shuffle(manifest.len().max(1), Rng::new(cfg.seed)),
    )?;
    let shards = cfg.threads.max(1);
    let window = DEFAULT_SHARD_WINDOW;
    let ds = sharded_reader_hier(samples, hier, shards, window)
        .parallel_map_ahead(cfg.threads, window * shards, f)
        .ignore_errors()
        .batch(cfg.batch, true)
        .parallel_map(1, move |samples| {
            ImageBatch::assemble(samples, num_classes)
        })
        .prefetch(cfg.prefetch);
    Ok(ds)
}

/// Run the mini-application with ingest routed through a storage
/// hierarchy (`dlio train --device hier:<preset>`), no checkpointing.
pub fn run_hier(
    hier: Arc<StorageHierarchy>,
    rt: &Runtime,
    manifest: &Manifest,
    cfg: &MiniAppConfig,
) -> Result<MiniAppResult> {
    if manifest.len() < cfg.batch {
        return Err(anyhow!(
            "corpus of {} images cannot fill a batch of {}",
            manifest.len(), cfg.batch
        ));
    }
    let mut trainer = Trainer::new(rt, &cfg.profile, cfg.batch, cfg.seed)?;
    let mut ds = input_pipeline_hier(hier, rt, manifest, cfg)?;
    drive(&mut trainer, &mut ds, Ckpt::None, cfg.iterations, usize::MAX)
}

/// Run the mini-application without checkpointing.
pub fn run(
    sim: Arc<StorageSim>,
    rt: &Runtime,
    manifest: &Manifest,
    cfg: &MiniAppConfig,
) -> Result<MiniAppResult> {
    run_with_checkpoints(sim, rt, manifest, &CkptStudyConfig {
        mini: cfg.clone(),
        target: CheckpointTarget::None,
        interval: usize::MAX,
        max_to_keep: 5,
    })
}

enum Ckpt {
    None,
    Direct(Saver),
    Bb(BurstBuffer),
}

/// Build the checkpoint sink for `target`.  With `route` set, Direct
/// saves go through the storage hierarchy — the placement policy
/// picks the tier, exactly like the routed ingest reads.
fn ckpt_sink(
    sim: &Arc<StorageSim>,
    profile: &ProfileMeta,
    target: &CheckpointTarget,
    max_to_keep: usize,
    route: Option<&Arc<StorageHierarchy>>,
) -> Result<Ckpt> {
    Ok(match target {
        CheckpointTarget::None => Ckpt::None,
        CheckpointTarget::Direct(dev) => {
            let mut saver = Saver::new(
                Arc::clone(sim),
                profile.clone(),
                dev,
                "ckpt/model",
                max_to_keep,
            );
            if let Some(h) = route {
                saver.set_route(Arc::clone(h));
            }
            Ckpt::Direct(saver)
        }
        CheckpointTarget::BurstBuffer { fast, slow } => {
            Ckpt::Bb(BurstBuffer::new(
                Arc::clone(sim),
                profile.clone(),
                fast,
                slow,
                "ckpt/model",
                max_to_keep,
            )?)
        }
    })
}

/// The shared training loop: one [`StepRecord`] per iteration,
/// checkpointing every `interval` iterations (§IV-C: 100 iters, ckpt
/// every 20).
fn drive(
    trainer: &mut Trainer,
    ds: &mut crate::pipeline::prefetch::Prefetch<ImageBatch>,
    mut ckpt: Ckpt,
    iterations: usize,
    interval: usize,
) -> Result<MiniAppResult> {
    let mut result = MiniAppResult {
        steps: 0,
        images: 0,
        total_secs: 0.0,
        ingest_wait_secs: 0.0,
        compute_secs: 0.0,
        ckpt_secs: 0.0,
        ckpt_durations: Vec::new(),
        losses: Vec::new(),
        step_records: Vec::new(),
    };

    let total = Timer::start();
    for it in 0..iterations {
        let start_secs = total.secs();
        let wait = Timer::start();
        let batch = match ds.next() {
            None => break, // corpus exhausted (one-epoch runs)
            Some(b) => b?,
        };
        let input_wait_secs = wait.secs();
        result.ingest_wait_secs += input_wait_secs;

        let compute = Timer::start();
        let loss = trainer.step(&batch)?;
        let compute_secs = compute.secs();
        result.compute_secs += compute_secs;
        result.losses.push(loss);
        result.steps += 1;
        result.images += batch.batch as u64;

        let mut ckpt_stall_secs = 0.0;
        if (it + 1) % interval.max(1) == 0 {
            let t = Timer::start();
            match &mut ckpt {
                Ckpt::None => {}
                Ckpt::Direct(saver) => {
                    saver.save(trainer.state(), trainer.step_count())?;
                }
                Ckpt::Bb(bb) => {
                    bb.save(trainer.state(), trainer.step_count())?;
                }
            }
            let dt = t.secs();
            if !matches!(ckpt, Ckpt::None) {
                ckpt_stall_secs = dt;
                result.ckpt_secs += dt;
                result.ckpt_durations.push(dt);
            }
        }
        result.step_records.push(StepRecord {
            step: it as u64,
            start_secs,
            input_wait_secs,
            compute_secs,
            ckpt_stall_secs,
            images: batch.batch as u64,
        });
    }
    result.total_secs = total.secs();
    // The BurstBuffer drop below blocks until drains complete, but the
    // paper's runtime measurement ends when *training* ends — we have
    // already captured total_secs.
    drop(ckpt);
    Ok(result)
}

/// Run the mini-application, optionally checkpointing every
/// `cfg.interval` iterations.
pub fn run_with_checkpoints(
    sim: Arc<StorageSim>,
    rt: &Runtime,
    manifest: &Manifest,
    cfg: &CkptStudyConfig,
) -> Result<MiniAppResult> {
    let mini = &cfg.mini;
    if manifest.len() < mini.batch {
        return Err(anyhow!(
            "corpus of {} images cannot fill a batch of {}",
            manifest.len(), mini.batch
        ));
    }
    let mut trainer = Trainer::new(rt, &mini.profile, mini.batch, mini.seed)?;
    let profile = trainer.profile().clone();
    let ckpt = ckpt_sink(&sim, &profile, &cfg.target, cfg.max_to_keep, None)?;
    let mut ds = input_pipeline(Arc::clone(&sim), rt, manifest, mini)?;
    drive(&mut trainer, &mut ds, ckpt, mini.iterations, cfg.interval)
}

/// Hierarchy-routed variant of [`run_with_checkpoints`]
/// (`dlio ckpt-study --device hier:<preset>`): ingest reads go
/// through the hierarchy and Direct checkpoint saves are routed the
/// same way.
pub fn run_with_checkpoints_hier(
    sim: Arc<StorageSim>,
    hier: Arc<StorageHierarchy>,
    rt: &Runtime,
    manifest: &Manifest,
    cfg: &CkptStudyConfig,
) -> Result<MiniAppResult> {
    let mini = &cfg.mini;
    if manifest.len() < mini.batch {
        return Err(anyhow!(
            "corpus of {} images cannot fill a batch of {}",
            manifest.len(), mini.batch
        ));
    }
    let mut trainer = Trainer::new(rt, &mini.profile, mini.batch, mini.seed)?;
    let profile = trainer.profile().clone();
    let ckpt =
        ckpt_sink(&sim, &profile, &cfg.target, cfg.max_to_keep, Some(&hier))?;
    let mut ds = input_pipeline_hier(Arc::clone(&hier), rt, manifest, mini)?;
    drive(&mut trainer, &mut ds, ckpt, mini.iterations, cfg.interval)
}
