//! Experiment coordination: the paper's three studies wired onto the
//! substrates.  Each bench/figure driver composes these runners; the
//! `dlio` binary exposes them as subcommands.

pub mod fault_sweep;
pub mod fixtures;
pub mod fleet_sweep;
pub mod microbench;
pub mod miniapp;
pub mod overlap_sweep;
pub mod qos_sweep;
pub mod sim_train;
pub mod tier_sweep;
pub mod trace_record;
pub mod workload;

pub use fault_sweep::{FaultSweepConfig, FaultSweepRow};
pub use fixtures::{
    build_hierarchy, build_hierarchy_with_policy, ensure_corpus, make_sim,
    StorageTarget,
};
pub use fleet_sweep::{FleetSweepConfig, FleetSweepRow};
pub use microbench::MicrobenchResult;
pub use miniapp::MiniAppResult;
pub use overlap_sweep::{OverlapSweepConfig, OverlapSweepRow};
pub use qos_sweep::{QosSweepCell, QosSweepConfig};
pub use sim_train::{SimTrainConfig, SimTrainResult};
pub use tier_sweep::{TierSweepCell, TierSweepConfig};
pub use trace_record::{TraceRecordConfig, TraceRecordResult};
