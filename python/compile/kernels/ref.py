"""Pure-jnp correctness oracle for the fused preprocess kernel.

Two references:

* :func:`preprocess_ref` — the *specification* oracle: convert u8->f32,
  normalize, then ``jax.image.resize(method="linear")``.  This is what
  TensorFlow's ``convert_image_dtype`` + ``resize_images`` compute.
* :func:`preprocess_matmul_ref` — the *algorithmic* oracle: the same
  matmul-form resize the Pallas kernel uses, in plain jnp.  The kernel
  must match this bit-for-bit up to float tolerance; the matmul form in
  turn must match the specification oracle (tested in
  ``tests/test_kernel.py``), closing the chain
  kernel == matmul-form == jax.image.resize.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .resize import IMAGENET_MEAN, IMAGENET_STD, resize_weights

__all__ = ["normalize_ref", "preprocess_ref", "preprocess_matmul_ref"]


def normalize_ref(images_u8: jax.Array,
                  mean=IMAGENET_MEAN, std=IMAGENET_STD) -> jax.Array:
    """u8 [B,H,W,C] -> normalized f32 [B,H,W,C]."""
    x = images_u8.astype(jnp.float32) / 255.0
    mean_a = jnp.asarray(mean, dtype=jnp.float32)
    std_a = jnp.asarray(std, dtype=jnp.float32)
    return (x - mean_a) / std_a


def preprocess_ref(images_u8: jax.Array, out_size: int,
                   mean=IMAGENET_MEAN, std=IMAGENET_STD) -> jax.Array:
    """Specification oracle: normalize then jax.image.resize linear."""
    x = normalize_ref(images_u8, mean, std)
    b, _, _, c = x.shape
    # antialias=False matches TF1's resize_images (the paper's pipeline):
    # plain bilinear taps, no kernel widening on downsample.
    return jax.image.resize(x, (b, out_size, out_size, c), method="linear",
                            antialias=False)


def preprocess_matmul_ref(images_u8: jax.Array, out_size: int,
                          mean=IMAGENET_MEAN, std=IMAGENET_STD) -> jax.Array:
    """Algorithmic oracle: the kernel's matmul-form resize in plain jnp."""
    x = normalize_ref(images_u8, mean, std)
    _, h, w, _ = x.shape
    ry = jnp.asarray(resize_weights(h, out_size))
    rx = jnp.asarray(resize_weights(w, out_size))
    # out[b,oh,ow,c] = Ry[oh,h] X[b,h,w,c] Rx[ow,w]
    t = jnp.einsum("oh,bhwc->bowc", ry, x)
    return jnp.einsum("bowc,pw->bopc", t, rx)
