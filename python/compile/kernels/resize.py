"""L1 Pallas kernel: fused decode-normalize-bilinear-resize.

The paper's per-image map-function hot spot is
``decode_jpeg -> convert_image_dtype -> resize_images``.  On TPU we do
not port the CUDA-style gather loop; instead the bilinear resample is
restructured as two dense matmuls so it runs on the MXU systolic array
(see DESIGN.md §3, §8)::

    out[oh, ow, c] = sum_h sum_w Ry[oh, h] * X[h, w, c] * Rx[ow, w]

``Ry``/``Rx`` are precomputed interpolation-weight matrices (each row
has at most two non-zeros — the two bilinear taps), built with the same
half-pixel-center convention as ``jax.image.resize(..., "linear")``.

The kernel fuses:
  1. u8 -> f32 conversion and scale to [0, 1]   (convert_image_dtype)
  2. per-channel mean/std normalization
  3. the two resize matmuls                      (resize_images)

Grid: one image per grid step; the whole image block plus both weight
matrices are VMEM-resident (~1.9 MB at 256->224, see DESIGN.md §8).

Pallas is invoked with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and correctness (vs ``ref.py``) is what we
validate here; real-TPU efficiency is estimated analytically.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

__all__ = [
    "resize_weights",
    "fused_preprocess",
    "IMAGENET_MEAN",
    "IMAGENET_STD",
]

# Channel statistics used by the normalization stage (ImageNet values,
# the conventional choice for AlexNet-style training).
IMAGENET_MEAN = (0.485, 0.456, 0.406)
IMAGENET_STD = (0.229, 0.224, 0.225)


def resize_weights(in_size: int, out_size: int) -> np.ndarray:
    """Bilinear interpolation weight matrix W[out_size, in_size].

    Uses the half-pixel-center convention of ``jax.image.resize`` with
    method="linear": source coordinate of output pixel ``o`` is
    ``(o + 0.5) * in/out - 0.5``, clamped taps, triangle kernel.
    Each row sums to 1.
    """
    if in_size <= 0 or out_size <= 0:
        raise ValueError(f"sizes must be positive, got {in_size}->{out_size}")
    scale = in_size / out_size
    w = np.zeros((out_size, in_size), dtype=np.float64)
    for o in range(out_size):
        src = (o + 0.5) * scale - 0.5
        lo = int(np.floor(src))
        frac = src - lo
        lo_c = min(max(lo, 0), in_size - 1)
        hi_c = min(max(lo + 1, 0), in_size - 1)
        w[o, lo_c] += 1.0 - frac
        w[o, hi_c] += frac
    return w.astype(np.float32)


def _preprocess_kernel(x_ref, ry_ref, rx_ref, mean_ref, std_ref, o_ref):
    """Pallas body: one image per grid step.

    x_ref:  u8  [1, H, W, C]   raw decoded pixels (one image block)
    ry_ref: f32 [OH, H]        row interpolation weights
    rx_ref: f32 [OW, W]        col interpolation weights
    mean_ref/std_ref: f32 [C]
    o_ref:  f32 [1, OH, OW, C]
    """
    x = x_ref[0].astype(jnp.float32) * (1.0 / 255.0)  # convert_image_dtype
    x = (x - mean_ref[...]) / std_ref[...]              # normalize
    ry = ry_ref[...]
    rx = rx_ref[...]
    # Row resample on the MXU: [OH,H] x [H, W*C] -> [OH, W, C]
    h, w, c = x.shape
    t = jnp.dot(ry, x.reshape(h, w * c)).reshape(ry.shape[0], w, c)
    # Col resample: contract W of t[OH,W,C] with W of rx[OW,W] -> [OH,C,OW]
    t = jax.lax.dot_general(
        t, rx, dimension_numbers=(((1,), (1,)), ((), ()))
    )  # [OH, C, OW]
    o_ref[0] = jnp.transpose(t, (0, 2, 1))


@functools.partial(jax.jit, static_argnums=(1,))
def _jit_marker(x, out_size):  # pragma: no cover - convenience only
    return fused_preprocess(x, out_size)


def fused_preprocess(images: jax.Array, out_size: int,
                     mean=IMAGENET_MEAN, std=IMAGENET_STD) -> jax.Array:
    """Fused u8->normalized-f32 bilinear resize, batched.

    images: u8 [B, H, W, C]  ->  f32 [B, out_size, out_size, C]
    """
    if images.ndim != 4:
        raise ValueError(f"expected [B,H,W,C], got shape {images.shape}")
    b, h, w, c = images.shape
    ry = jnp.asarray(resize_weights(h, out_size))
    rx = jnp.asarray(resize_weights(w, out_size))
    mean_a = jnp.asarray(mean, dtype=jnp.float32)
    std_a = jnp.asarray(std, dtype=jnp.float32)

    if b == 1:
        # Grid-free single-image form.  This is what the AOT artifacts
        # use (the map function preprocesses one image per call): the
        # whole image + weight matrices form one VMEM-resident block,
        # and the lowered HLO contains no `while` loop — XLA 0.5.1's
        # CPU runtime (the rust side) mis-executes the 1-trip loop the
        # grid form lowers to under interpret=True.
        return pl.pallas_call(
            _preprocess_kernel,
            out_shape=jax.ShapeDtypeStruct((1, out_size, out_size, c),
                                           jnp.float32),
            interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
        )(images, ry, rx, mean_a, std_a)

    # Batched form: one image per grid step (the TPU schedule of
    # DESIGN.md §8).  Used by python-side tests and TPU targets.
    grid = (b,)
    return pl.pallas_call(
        _preprocess_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, h, w, c), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((out_size, h), lambda i: (0, 0)),
            pl.BlockSpec((out_size, w), lambda i: (0, 0)),
            pl.BlockSpec((c,), lambda i: (0,)),
            pl.BlockSpec((c,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec(
            (1, out_size, out_size, c), lambda i: (i, 0, 0, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, out_size, out_size, c),
                                       jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(images, ry, rx, mean_a, std_a)
