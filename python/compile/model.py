"""L2: AlexNet forward/backward + Adam as a single jitted train step.

This is the "accelerator compute" of the paper's mini-application
(§III-B): AlexNet [Krizhevsky'12] — five convolutions, three max-pools,
three fully-connected layers, ReLU — classifying Caltech-101-style
batches (102 classes), driven by the Adam optimizer.

The module defines *profiles* that scale the network to the benchmark
testbed while preserving the structure (5 conv / 3 pool / 3 fc):

* ``paper`` — faithful AlexNet: 224x224x3 input, 4096-wide FC layers.
  Checkpoint (params + Adam moments) ≈ 700 MB, matching the paper's
  "roughly 600 MB" (§VII).
* ``mini``  — 64x64x3 input, narrowed channels.  This keeps a CPU-PJRT
  train step in the paper's compute regime *relative to* the simulated
  storage devices (DESIGN.md §6) and is the default for benches.
* ``micro`` — 32x32x3, further narrowed; used by fast tests/benches.

Everything here runs at *build time only*: ``aot.py`` lowers
``make_train_step`` and the Pallas-fused ``make_preprocess`` to HLO
text which the rust coordinator loads via PJRT.  Python is never on
the request path.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.resize import fused_preprocess

NUM_CLASSES = 102  # Caltech 101 + "Google background" class (§IV-B)

# ---------------------------------------------------------------------------
# Profiles
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ConvSpec:
    ksize: int
    stride: int
    out_ch: int
    pool: bool  # 3x3 stride-2 max pool after this conv


@dataclass(frozen=True)
class Profile:
    """A structurally-AlexNet network scaled to a target input size."""

    name: str
    input_size: int
    convs: Tuple[ConvSpec, ...]
    fc_widths: Tuple[int, ...]  # hidden FC widths; classifier appended
    num_classes: int = NUM_CLASSES

    def spatial_after_convs(self) -> int:
        s = self.input_size
        for c in self.convs:
            s = -(-s // c.stride)  # SAME conv
            if c.pool:
                s = -(-s // 2)  # 3x3 stride-2 SAME max pool
        return s


# Faithful AlexNet (single-tower variant, as in the paper's ~200-line
# mini-app): conv1 11x11/4 96, conv2 5x5 256, conv3/4 3x3 384, conv5 3x3 256.
PAPER = Profile(
    name="paper",
    input_size=224,
    convs=(
        ConvSpec(11, 4, 96, True),
        ConvSpec(5, 1, 256, True),
        ConvSpec(3, 1, 384, False),
        ConvSpec(3, 1, 384, False),
        ConvSpec(3, 1, 256, True),
    ),
    fc_widths=(4096, 4096),
)

MINI = Profile(
    name="mini",
    input_size=64,
    convs=(
        ConvSpec(7, 2, 64, True),
        ConvSpec(5, 1, 192, True),
        ConvSpec(3, 1, 256, False),
        ConvSpec(3, 1, 256, False),
        ConvSpec(3, 1, 192, True),
    ),
    fc_widths=(1024, 1024),
)

MICRO = Profile(
    name="micro",
    input_size=32,
    convs=(
        ConvSpec(5, 2, 32, True),
        ConvSpec(3, 1, 64, False),
        ConvSpec(3, 1, 64, True),
    ),
    fc_widths=(256,),
)

PROFILES: Dict[str, Profile] = {p.name: p for p in (PAPER, MINI, MICRO)}

# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def param_specs(profile: Profile) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list — the HLO argument/result order contract
    shared with the rust side (emitted into model_meta.json)."""
    specs: List[Tuple[str, Tuple[int, ...]]] = []
    in_ch = 3
    for i, c in enumerate(profile.convs, start=1):
        specs.append((f"conv{i}/kernel", (c.ksize, c.ksize, in_ch, c.out_ch)))
        specs.append((f"conv{i}/bias", (c.out_ch,)))
        in_ch = c.out_ch
    s = profile.spatial_after_convs()
    fan_in = s * s * in_ch
    widths = list(profile.fc_widths) + [profile.num_classes]
    for i, w in enumerate(widths, start=1):
        specs.append((f"fc{i}/kernel", (fan_in, w)))
        specs.append((f"fc{i}/bias", (w,)))
        fan_in = w
    return specs


def init_params(profile: Profile, seed: int = 0) -> List[jax.Array]:
    """He-normal kernels, zero biases.  Used by python tests; the rust
    coordinator re-implements the identical initializer (model::params)."""
    out: List[jax.Array] = []
    key = jax.random.PRNGKey(seed)
    for name, shape in param_specs(profile):
        key, sub = jax.random.split(key)
        if name.endswith("bias"):
            out.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = int(np.prod(shape[:-1]))
            std = float(np.sqrt(2.0 / fan_in))
            out.append(jax.random.normal(sub, shape, jnp.float32) * std)
    return out


def num_params(profile: Profile) -> int:
    return sum(int(np.prod(s)) for _, s in param_specs(profile))


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


def forward(profile: Profile, params: List[jax.Array],
            images: jax.Array) -> jax.Array:
    """images f32 [B, S, S, 3] -> logits f32 [B, num_classes]."""
    specs = param_specs(profile)
    idx = 0
    x = images
    for c in profile.convs:
        k, b = params[idx], params[idx + 1]
        idx += 2
        x = jax.lax.conv_general_dilated(
            x, k,
            window_strides=(c.stride, c.stride),
            padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        ) + b
        x = jax.nn.relu(x)
        if c.pool:
            x = jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max,
                window_dimensions=(1, 3, 3, 1),
                window_strides=(1, 2, 2, 1),
                padding="SAME",
            )
    b_sz = x.shape[0]
    x = x.reshape(b_sz, -1)
    n_fc = len(profile.fc_widths) + 1
    for i in range(n_fc):
        k, b = params[idx], params[idx + 1]
        idx += 2
        x = x @ k + b
        if i < n_fc - 1:
            x = jax.nn.relu(x)
    assert idx == len(specs)
    return x


def loss_fn(profile: Profile, params: List[jax.Array],
            images: jax.Array, labels_onehot: jax.Array) -> jax.Array:
    """Softmax cross-entropy against one-hot labels (mean over batch)."""
    logits = forward(profile, params, images)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.sum(labels_onehot * logp, axis=-1))


# ---------------------------------------------------------------------------
# Adam optimizer (tf.train.AdamOptimizer defaults, §III-B)
# ---------------------------------------------------------------------------

ADAM_LR = 1e-4
ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


def make_train_step(profile: Profile):
    """Build the jittable flat train step.

    Flat signature (the artifact ABI, mirrored in model_meta.json):

        inputs : [P params..., P m..., P v..., step f32[], images, labels]
        outputs: (P new_params..., P new_m..., P new_v..., new_step, loss)
    """
    n = len(param_specs(profile))

    def train_step(*args):
        params = list(args[0:n])
        m = list(args[n:2 * n])
        v = list(args[2 * n:3 * n])
        step = args[3 * n]
        images = args[3 * n + 1]
        labels = args[3 * n + 2]

        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(profile, p, images, labels))(params)

        t = step + 1.0
        bc1 = 1.0 - ADAM_B1 ** t
        bc2 = 1.0 - ADAM_B2 ** t
        new_params, new_m, new_v = [], [], []
        for p, g, mi, vi in zip(params, grads, m, v):
            mi = ADAM_B1 * mi + (1.0 - ADAM_B1) * g
            vi = ADAM_B2 * vi + (1.0 - ADAM_B2) * (g * g)
            update = ADAM_LR * (mi / bc1) / (jnp.sqrt(vi / bc2) + ADAM_EPS)
            new_params.append(p - update)
            new_m.append(mi)
            new_v.append(vi)
        return tuple(new_params + new_m + new_v + [t, loss])

    return train_step


def train_step_example_args(profile: Profile, batch: int):
    """ShapeDtypeStructs for lowering make_train_step."""
    sds = jax.ShapeDtypeStruct
    specs = param_specs(profile)
    args = [sds(shape, jnp.float32) for _, shape in specs] * 3
    args.append(sds((), jnp.float32))  # step
    args.append(sds((batch, profile.input_size, profile.input_size, 3),
                    jnp.float32))
    args.append(sds((batch, profile.num_classes), jnp.float32))
    return args


# ---------------------------------------------------------------------------
# Preprocess graph (wraps the L1 Pallas kernel)
# ---------------------------------------------------------------------------


def make_preprocess(src_size: int, out_size: int):
    """u8 [B, src, src, 3] -> f32 [B, out, out, 3] via the fused Pallas
    kernel.  One HLO artifact per (src, out) bucket (DESIGN.md §2)."""

    def preprocess(images_u8):
        return (fused_preprocess(images_u8, out_size),)

    return preprocess


def preprocess_example_args(src_size: int, batch: int = 1):
    return [jax.ShapeDtypeStruct((batch, src_size, src_size, 3), jnp.uint8)]
