"""AOT lowering: JAX (L2) + Pallas (L1) -> HLO text artifacts for rust (L3).

Interchange format is HLO *text*, not ``lowered.compile().serialize()``:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the
xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``).
The text parser on the rust side reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).

Emitted artifacts (``make artifacts``; python never runs afterwards):

  artifacts/
    preprocess_{src}_to_{out}.hlo.txt   one per corpus source-dim bucket
                                        x model input size (DESIGN.md §2)
    train_{profile}_b{batch}.hlo.txt    AlexNet fwd/bwd/Adam step
    model_meta.json                     the ABI contract consumed by rust:
                                        param order/shapes, artifact list,
                                        optimizer constants, norm stats

Usage: ``python -m compile.aot --out-dir ../artifacts``
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels.resize import IMAGENET_MEAN, IMAGENET_STD

# (src, out) resize buckets.  src=96 is the Caltech-101-like corpus
# bucket (median ~12 kB files), src=256 the ImageNet-subset-like bucket
# (median ~112 kB files); outs are the model profile input sizes.
DEFAULT_BUCKETS = [(96, 32), (256, 32), (96, 64), (256, 64)]
PAPER_BUCKETS = [(96, 224), (256, 224)]

DEFAULT_TRAIN = [
    ("micro", 16), ("micro", 32), ("micro", 64), ("micro", 128),
    ("mini", 16), ("mini", 32), ("mini", 64), ("mini", 128),
]
PAPER_TRAIN = [("paper", 64)]


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True so the
    rust side always unwraps a tuple).

    ``print_large_constants=True`` is load-bearing: the default printer
    elides constants over ~500 elements as ``{...}``, which XLA 0.5.1's
    text parser silently reads back as *zeros* — the resize weight
    matrices and any folded model constants would vanish.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    text = comp.as_hlo_text(print_large_constants=True)
    if "{...}" in text:
        raise RuntimeError("HLO text still contains elided constants")
    return text


def lower_preprocess(src: int, out: int, batch: int = 1) -> str:
    fn = M.make_preprocess(src, out)
    lowered = jax.jit(fn).lower(*M.preprocess_example_args(src, batch))
    return to_hlo_text(lowered)


def lower_train(profile: M.Profile, batch: int) -> str:
    fn = M.make_train_step(profile)
    lowered = jax.jit(fn).lower(*M.train_step_example_args(profile, batch))
    return to_hlo_text(lowered)


def profile_meta(profile: M.Profile) -> dict:
    specs = M.param_specs(profile)
    return {
        "name": profile.name,
        "input_size": profile.input_size,
        "num_classes": profile.num_classes,
        "num_param_tensors": len(specs),
        "num_params": M.num_params(profile),
        "params": [
            {"name": n, "shape": list(s)} for n, s in specs
        ],
        # Flat ABI: [params*, m*, v*, step, images, labels] ->
        #           (params*, m*, v*, step, loss)
        "num_inputs": 3 * len(specs) + 3,
        "num_outputs": 3 * len(specs) + 2,
    }


def write_if_changed(path: str, text: str) -> bool:
    if os.path.exists(path):
        with open(path) as f:
            if f.read() == text:
                return False
    with open(path, "w") as f:
        f.write(text)
    return True


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None,
                    help="legacy: single-HLO marker path (Makefile stamp)")
    ap.add_argument("--paper", action="store_true",
                    help="also emit full-size 224x224 AlexNet artifacts "
                         "(slow; DLIO_PAPER=1 equivalent)")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    out_dir = args.out_dir
    if args.out:
        out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)

    paper = args.paper or os.environ.get("DLIO_PAPER") == "1"
    buckets = DEFAULT_BUCKETS + (PAPER_BUCKETS if paper else [])
    trains = DEFAULT_TRAIN + (PAPER_TRAIN if paper else [])

    artifacts = []
    t0 = time.time()
    for src, out in buckets:
        name = f"preprocess_{src}_to_{out}.hlo.txt"
        path = os.path.join(out_dir, name)
        if args.force or not os.path.exists(path):
            text = lower_preprocess(src, out)
            write_if_changed(path, text)
            print(f"[aot] {name}  ({len(text)//1024} KiB, "
                  f"{time.time()-t0:.1f}s)")
        artifacts.append({
            "kind": "preprocess", "file": name,
            "src_size": src, "out_size": out, "batch": 1,
        })

    for prof_name, batch in trains:
        profile = M.PROFILES[prof_name]
        name = f"train_{prof_name}_b{batch}.hlo.txt"
        path = os.path.join(out_dir, name)
        if args.force or not os.path.exists(path):
            text = lower_train(profile, batch)
            write_if_changed(path, text)
            print(f"[aot] {name}  ({len(text)//1024} KiB, "
                  f"{time.time()-t0:.1f}s)")
        artifacts.append({
            "kind": "train", "file": name,
            "profile": prof_name, "batch": batch,
        })

    meta = {
        "format_version": 1,
        "adam": {"lr": M.ADAM_LR, "b1": M.ADAM_B1, "b2": M.ADAM_B2,
                 "eps": M.ADAM_EPS},
        "norm_mean": list(IMAGENET_MEAN),
        "norm_std": list(IMAGENET_STD),
        "profiles": {n: profile_meta(p) for n, p in M.PROFILES.items()},
        "artifacts": artifacts,
    }
    meta_path = os.path.join(out_dir, "model_meta.json")
    write_if_changed(meta_path, json.dumps(meta, indent=1))
    print(f"[aot] model_meta.json  ({len(artifacts)} artifacts, "
          f"{time.time()-t0:.1f}s total)")

    if args.out:
        # Makefile stamp: ensure the marker file exists.
        first = os.path.join(out_dir, artifacts[0]["file"])
        if os.path.abspath(first) != os.path.abspath(args.out):
            with open(args.out, "w") as f:
                f.write(f"# see {out_dir}\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
