"""AOT artifact contract tests: HLO text validity + meta ABI consistency."""

import json
import os

import pytest

from compile import aot, model as M


class TestHloText:
    def test_preprocess_lowering_is_hlo_text(self):
        text = aot.lower_preprocess(96, 64)
        assert text.startswith("HloModule")
        assert "ENTRY" in text

    def test_train_lowering_is_hlo_text(self):
        text = aot.lower_train(M.PROFILES["micro"], 2)
        assert text.startswith("HloModule")
        # fwd+bwd must contain convolutions (fwd + grad)
        assert text.count("convolution") >= 2

    def test_preprocess_has_no_custom_call(self):
        # interpret=True must lower pallas to plain HLO — a Mosaic
        # custom-call would be unloadable by the CPU PJRT client.
        text = aot.lower_preprocess(96, 64)
        assert "custom-call" not in text or "mosaic" not in text.lower()

    def test_lowering_deterministic(self):
        assert aot.lower_preprocess(96, 32) == aot.lower_preprocess(96, 32)


class TestMeta:
    def test_profile_meta_counts(self):
        for name, p in M.PROFILES.items():
            meta = aot.profile_meta(p)
            n = meta["num_param_tensors"]
            assert meta["num_inputs"] == 3 * n + 3
            assert meta["num_outputs"] == 3 * n + 2
            assert len(meta["params"]) == n
            total = sum(
                int(__import__("numpy").prod(q["shape"]))
                for q in meta["params"])
            assert total == meta["num_params"]

    def test_meta_json_roundtrip(self, tmp_path):
        meta = {"profiles": {n: aot.profile_meta(p)
                             for n, p in M.PROFILES.items()}}
        path = tmp_path / "m.json"
        path.write_text(json.dumps(meta))
        back = json.loads(path.read_text())
        assert back == meta


class TestWriteIfChanged:
    def test_skips_identical(self, tmp_path):
        p = str(tmp_path / "x.txt")
        assert aot.write_if_changed(p, "abc") is True
        assert aot.write_if_changed(p, "abc") is False
        assert aot.write_if_changed(p, "abcd") is True


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__),
                                    "../../artifacts/model_meta.json")),
    reason="artifacts not built")
class TestBuiltArtifacts:
    """Validate the artifacts actually shipped to the rust side."""

    @pytest.fixture()
    def meta(self):
        path = os.path.join(os.path.dirname(__file__),
                            "../../artifacts/model_meta.json")
        with open(path) as f:
            return json.load(f)

    def test_all_artifact_files_exist(self, meta):
        base = os.path.join(os.path.dirname(__file__), "../../artifacts")
        for a in meta["artifacts"]:
            assert os.path.exists(os.path.join(base, a["file"])), a

    def test_adam_constants_match_model(self, meta):
        assert meta["adam"]["lr"] == M.ADAM_LR
        assert meta["adam"]["b1"] == M.ADAM_B1
        assert meta["adam"]["b2"] == M.ADAM_B2

    def test_artifacts_cover_default_buckets(self, meta):
        pre = {(a["src_size"], a["out_size"])
               for a in meta["artifacts"] if a["kind"] == "preprocess"}
        for bucket in aot.DEFAULT_BUCKETS:
            assert bucket in pre
