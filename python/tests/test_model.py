"""L2 correctness: AlexNet profiles, shapes, loss/grad behaviour, Adam."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


def make_batch(profile, batch, seed=0):
    rng = np.random.default_rng(seed)
    imgs = rng.standard_normal(
        (batch, profile.input_size, profile.input_size, 3)).astype(np.float32)
    labels = np.zeros((batch, profile.num_classes), np.float32)
    labels[np.arange(batch), rng.integers(0, profile.num_classes, batch)] = 1
    return jnp.asarray(imgs), jnp.asarray(labels)


class TestProfiles:
    @pytest.mark.parametrize("name", ["paper", "mini", "micro"])
    def test_profile_registered(self, name):
        assert M.PROFILES[name].name == name

    def test_paper_is_faithful_alexnet(self):
        p = M.PROFILES["paper"]
        assert p.input_size == 224
        assert [c.out_ch for c in p.convs] == [96, 256, 384, 384, 256]
        assert [c.ksize for c in p.convs] == [11, 5, 3, 3, 3]
        assert p.fc_widths == (4096, 4096)
        # 5 convs + 3 FCs, 3 pools — the AlexNet structure (§III-B)
        assert sum(c.pool for c in p.convs) == 3

    def test_paper_checkpoint_size_near_600mb(self):
        # §VII: "roughly 600 MB in the case of AlexNet" (params + Adam
        # moments).  w + m + v, f32.
        n = M.num_params(M.PROFILES["paper"])
        ckpt_mb = n * 3 * 4 / 1e6
        assert 450 <= ckpt_mb <= 900, ckpt_mb

    def test_mini_structure_preserved(self):
        p = M.PROFILES["mini"]
        assert len(p.convs) == 5
        assert sum(c.pool for c in p.convs) == 3
        assert len(p.fc_widths) + 1 == 3

    def test_param_specs_order_convs_then_fcs(self):
        specs = M.param_specs(M.PROFILES["micro"])
        names = [n for n, _ in specs]
        assert names[0] == "conv1/kernel"
        assert names[-1].startswith("fc")
        assert names[-1].endswith("bias")
        # alternating kernel/bias
        for i, n in enumerate(names):
            assert n.endswith("kernel" if i % 2 == 0 else "bias")

    def test_spatial_after_convs(self):
        # micro: 32 -> conv s2 -> 16 -> pool -> 8 -> conv -> 8 -> pool -> 4
        assert M.PROFILES["micro"].spatial_after_convs() == 4
        # paper: 224/4=56 -> pool 28 -> pool 14 -> pool 7
        assert M.PROFILES["paper"].spatial_after_convs() == 7


class TestForward:
    def test_logit_shape_micro(self):
        p = M.PROFILES["micro"]
        params = M.init_params(p)
        imgs, _ = make_batch(p, 4)
        logits = M.forward(p, params, imgs)
        assert logits.shape == (4, p.num_classes)
        assert bool(jnp.isfinite(logits).all())

    def test_initial_loss_at_or_above_chance(self):
        # A randomly-initialized classifier cannot beat chance: the
        # cross-entropy must be >= ln(102) - eps and finite.  (He-init
        # on standard-normal inputs yields confident-but-wrong logits,
        # so the loss is typically well above ln(C).)
        p = M.PROFILES["micro"]
        params = M.init_params(p)
        imgs, labels = make_batch(p, 8)
        loss = float(M.loss_fn(p, params, imgs, labels))
        assert np.isfinite(loss)
        assert loss > np.log(p.num_classes) - 1.0, loss


class TestTrainStep:
    def _run_steps(self, profile, batch, steps):
        n = len(M.param_specs(profile))
        fn = jax.jit(M.make_train_step(profile))
        params = M.init_params(profile)
        m = [jnp.zeros_like(p) for p in params]
        v = [jnp.zeros_like(p) for p in params]
        step = jnp.float32(0)
        imgs, labels = make_batch(profile, batch)
        losses = []
        for _ in range(steps):
            out = fn(*params, *m, *v, step, imgs, labels)
            params, m, v = list(out[:n]), list(out[n:2*n]), list(out[2*n:3*n])
            step = out[3 * n]
            losses.append(float(out[3 * n + 1]))
        return losses, step

    def test_loss_decreases_on_fixed_batch(self):
        losses, step = self._run_steps(M.PROFILES["micro"], 8, 12)
        assert losses[-1] < losses[0], losses
        assert float(step) == 12.0

    def test_output_arity_matches_meta(self):
        p = M.PROFILES["micro"]
        n = len(M.param_specs(p))
        fn = jax.jit(M.make_train_step(p))
        params = M.init_params(p)
        zeros = [jnp.zeros_like(x) for x in params]
        imgs, labels = make_batch(p, 2)
        out = fn(*params, *zeros, *zeros, jnp.float32(0), imgs, labels)
        assert len(out) == 3 * n + 2

    def test_step_counter_increments(self):
        _, step = self._run_steps(M.PROFILES["micro"], 2, 3)
        assert float(step) == 3.0

    def test_adam_moments_move_from_zero(self):
        p = M.PROFILES["micro"]
        n = len(M.param_specs(p))
        fn = jax.jit(M.make_train_step(p))
        params = M.init_params(p)
        zeros = [jnp.zeros_like(x) for x in params]
        imgs, labels = make_batch(p, 2)
        out = fn(*params, *zeros, *zeros, jnp.float32(0), imgs, labels)
        m = out[n:2*n]
        assert any(float(jnp.abs(mi).max()) > 0 for mi in m)


class TestExampleArgs:
    def test_train_example_args_count(self):
        p = M.PROFILES["micro"]
        args = M.train_step_example_args(p, 4)
        assert len(args) == 3 * len(M.param_specs(p)) + 3
        assert args[-2].shape == (4, 32, 32, 3)
        assert args[-1].shape == (4, p.num_classes)

    def test_preprocess_example_args(self):
        (a,) = M.preprocess_example_args(96, batch=2)
        assert a.shape == (2, 96, 96, 3)
        assert a.dtype == jnp.uint8
