"""L1 correctness: Pallas fused preprocess kernel vs pure-jnp oracles.

Chain closed here (DESIGN.md §3):
    pallas kernel == matmul-form jnp ref == jax.image.resize spec.
Hypothesis sweeps shapes/dtypes; fixed cases pin the paper's actual
bucket geometries (96->64, 256->64, 256->224).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import (
    normalize_ref,
    preprocess_matmul_ref,
    preprocess_ref,
)
from compile.kernels.resize import (
    IMAGENET_MEAN,
    IMAGENET_STD,
    fused_preprocess,
    resize_weights,
)


def rand_u8(shape, seed=0):
    return np.random.default_rng(seed).integers(
        0, 256, size=shape, dtype=np.uint8)


# ---------------------------------------------------------------------------
# resize_weights invariants
# ---------------------------------------------------------------------------

class TestResizeWeights:
    @pytest.mark.parametrize("in_size,out_size",
                             [(96, 64), (256, 64), (256, 224), (64, 64),
                              (10, 30), (1, 4), (4, 1)])
    def test_rows_sum_to_one(self, in_size, out_size):
        w = resize_weights(in_size, out_size)
        np.testing.assert_allclose(w.sum(axis=1), 1.0, rtol=1e-6)

    @pytest.mark.parametrize("in_size,out_size", [(96, 64), (256, 224)])
    def test_at_most_two_taps(self, in_size, out_size):
        w = resize_weights(in_size, out_size)
        assert ((w != 0).sum(axis=1) <= 2).all()

    def test_identity_when_same_size(self):
        w = resize_weights(17, 17)
        np.testing.assert_allclose(w, np.eye(17, dtype=np.float32),
                                   atol=1e-7)

    def test_weights_nonnegative(self):
        for a, b in [(96, 64), (64, 96), (256, 224), (7, 13)]:
            assert (resize_weights(a, b) >= 0).all()

    def test_rejects_bad_sizes(self):
        with pytest.raises(ValueError):
            resize_weights(0, 4)
        with pytest.raises(ValueError):
            resize_weights(4, 0)

    def test_upsample_interpolates_linearly(self):
        # Resizing a linear ramp must reproduce a linear ramp exactly in
        # the interior (bilinear preserves degree-1 signals).
        w = resize_weights(16, 32)
        ramp = np.arange(16, dtype=np.float32)
        out = w @ ramp
        interior = out[2:-2]
        diffs = np.diff(interior)
        np.testing.assert_allclose(diffs, diffs[0], atol=1e-5)


# ---------------------------------------------------------------------------
# normalize
# ---------------------------------------------------------------------------

class TestNormalize:
    def test_zero_pixels_map_to_minus_mean_over_std(self):
        x = np.zeros((1, 4, 4, 3), np.uint8)
        out = np.asarray(normalize_ref(jnp.asarray(x)))
        expect = -(np.asarray(IMAGENET_MEAN) / np.asarray(IMAGENET_STD))
        np.testing.assert_allclose(out[0, 0, 0], expect, rtol=1e-6)

    def test_255_maps_to_one_normalized(self):
        x = np.full((1, 2, 2, 3), 255, np.uint8)
        out = np.asarray(normalize_ref(jnp.asarray(x)))
        expect = (1.0 - np.asarray(IMAGENET_MEAN)) / np.asarray(IMAGENET_STD)
        np.testing.assert_allclose(out[0, 1, 1], expect, rtol=1e-6)


# ---------------------------------------------------------------------------
# kernel vs oracles — fixed paper geometries
# ---------------------------------------------------------------------------

PAPER_BUCKETS = [(96, 64), (256, 64), (96, 32), (256, 32)]


class TestKernelVsRef:
    @pytest.mark.parametrize("src,out", PAPER_BUCKETS)
    def test_kernel_matches_matmul_ref(self, src, out):
        x = jnp.asarray(rand_u8((2, src, src, 3), seed=src * out))
        k = fused_preprocess(x, out)
        r = preprocess_matmul_ref(x, out)
        np.testing.assert_allclose(np.asarray(k), np.asarray(r),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("src,out", PAPER_BUCKETS)
    def test_matmul_ref_matches_spec(self, src, out):
        x = jnp.asarray(rand_u8((2, src, src, 3), seed=src + out))
        r = preprocess_matmul_ref(x, out)
        s = preprocess_ref(x, out)
        np.testing.assert_allclose(np.asarray(r), np.asarray(s),
                                   rtol=1e-4, atol=1e-4)

    def test_paper_full_geometry_256_to_224(self):
        x = jnp.asarray(rand_u8((1, 256, 256, 3), seed=7))
        k = fused_preprocess(x, 224)
        s = preprocess_ref(x, 224)
        np.testing.assert_allclose(np.asarray(k), np.asarray(s),
                                   rtol=1e-3, atol=1e-3)

    def test_constant_image_resizes_to_constant(self):
        x = jnp.asarray(np.full((1, 96, 96, 3), 128, np.uint8))
        k = np.asarray(fused_preprocess(x, 64))
        expect = (128.0 / 255.0 - np.asarray(IMAGENET_MEAN)) \
            / np.asarray(IMAGENET_STD)
        np.testing.assert_allclose(k, np.broadcast_to(expect, k.shape),
                                   rtol=1e-4)

    def test_output_shape_and_dtype(self):
        x = jnp.asarray(rand_u8((3, 96, 96, 3)))
        k = fused_preprocess(x, 64)
        assert k.shape == (3, 64, 64, 3)
        assert k.dtype == jnp.float32

    def test_batch_elements_independent(self):
        # Preprocessing image i must not depend on image j != i.
        a = rand_u8((2, 96, 96, 3), seed=1)
        b = a.copy()
        b[1] = rand_u8((96, 96, 3), seed=2)
        ka = np.asarray(fused_preprocess(jnp.asarray(a), 64))
        kb = np.asarray(fused_preprocess(jnp.asarray(b), 64))
        np.testing.assert_array_equal(ka[0], kb[0])
        assert np.abs(ka[1] - kb[1]).max() > 0

    def test_rejects_non_4d(self):
        with pytest.raises(ValueError):
            fused_preprocess(jnp.zeros((96, 96, 3), jnp.uint8), 64)


# ---------------------------------------------------------------------------
# hypothesis shape/dtype sweep
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    batch=st.integers(1, 3),
    src=st.integers(8, 64),
    out=st.integers(4, 48),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_hypothesis(batch, src, out, seed):
    x = jnp.asarray(rand_u8((batch, src, src, 3), seed=seed))
    k = fused_preprocess(x, out)
    r = preprocess_matmul_ref(x, out)
    np.testing.assert_allclose(np.asarray(k), np.asarray(r),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    src=st.integers(8, 48),
    out=st.integers(4, 48),
)
def test_matmul_form_matches_spec_hypothesis(src, out):
    x = jnp.asarray(rand_u8((1, src, src, 3), seed=src * 1000 + out))
    r = preprocess_matmul_ref(x, out)
    s = preprocess_ref(x, out)
    np.testing.assert_allclose(np.asarray(r), np.asarray(s),
                               rtol=1e-3, atol=1e-3)
