#!/usr/bin/env python3
"""Plot the `dlio tier-sweep --format json` matrix (DESIGN.md §12, §17).

Reads the sweep's JSON rows (one object per (hierarchy, policy,
workload) cell, each carrying a `tier_rows` array) and renders two
panels:

* per-tier hit/migration columns — for every cell, one bar group per
  tier with hits, migrations-in, and evictions side by side: where
  the placement policy put the data, visually;
* policy vs theta — for the read-write-mix cells (`zipf`/`uniform`),
  tier-0 hit fraction against the Zipf skew, one line per placement
  policy (averaged across hierarchies).  Run the sweep with several
  `--workloads zipf:0.6,zipf:0.9,zipf:1.2,uniform` tokens to get a
  multi-point curve; the cost-aware policy should track `freq` at
  high skew and hold migrations near zero at theta 0.

Stub-safe: when matplotlib is unavailable (offline CI), prints an
aligned ASCII summary of the same numbers instead of an image and
exits 0 — the JSON schema is exercised either way.

Usage:
    dlio tier-sweep --format json > tiers.json
    python3 python/plot_tier_sweep.py tiers.json --out tiers.png \
        [--workload hot]
"""

import argparse
import json
import sys


def load_rows(path, workload):
    with open(path) as f:
        rows = json.load(f)
    if not isinstance(rows, list) or not rows:
        raise SystemExit(f"{path}: expected a non-empty JSON array of cells")
    for key in ("hierarchy", "policy", "workload", "tier_rows"):
        if key not in rows[0]:
            raise SystemExit(f"{path}: cell missing {key!r} (schema drift?)")
    if workload:
        rows = [r for r in rows if r["workload"] == workload]
        if not rows:
            raise SystemExit(f"{path}: no cells for workload {workload!r}")
    return rows


def cell_label(row):
    return f"{row['hierarchy']}/{row['policy']}/{row['workload']}"


MIX_WORKLOADS = ("zipf", "uniform")


def mix_curves(rows):
    """(policy -> sorted [(theta, mean t0_hit_frac)]) over mix cells.

    `uniform` cells land at theta 0, so a standard sweep already
    yields a two-point curve per policy; hit fractions are averaged
    across hierarchies at each theta.
    """
    buckets = {}
    for r in rows:
        if r["workload"] not in MIX_WORKLOADS:
            continue
        buckets.setdefault(r["policy"], {}).setdefault(
            float(r["theta"]), []).append(float(r["t0_hit_frac"]))
    return {
        pol: sorted((th, sum(v) / len(v)) for th, v in pts.items())
        for pol, pts in buckets.items()
    }


def ascii_summary(rows):
    print("# tier-sweep: per-tier hit/migration columns (matplotlib "
          "unavailable: ASCII fallback)")
    width = max(len(cell_label(r)) for r in rows) + 2
    for row in rows:
        label = cell_label(row).ljust(width)
        cols = "  ".join(
            f"t{t['tier']}({t['device']}):hits={t['hits']}"
            f",mig={t['migrations_in']},ev={t['evictions']}"
            for t in row["tier_rows"]
        )
        print(f"{label}hit_frac={row['t0_hit_frac']:.2f}  {cols}")
    curves = mix_curves(rows)
    if curves:
        print("# policy vs theta (tier-0 hit fraction over mix cells, "
              "mean across hierarchies)")
        for pol in sorted(curves):
            pts = "  ".join(f"theta={th:.2f}:{hf:.2f}"
                            for th, hf in curves[pol])
            print(f"{pol.ljust(8)}{pts}")


def plot(rows, out):
    import matplotlib
    matplotlib.use("Agg")
    import matplotlib.pyplot as plt

    curves = mix_curves(rows)
    if curves:
        fig, (ax, ax2) = plt.subplots(
            1, 2, figsize=(max(6, 1.4 * len(rows)) + 4, 4),
            gridspec_kw={"width_ratios": [3, 1]})
    else:
        fig, ax = plt.subplots(figsize=(max(6, 1.4 * len(rows)), 4))
        ax2 = None
    series = [
        ("hits", lambda t: t["hits"]),
        ("migrations in", lambda t: t["migrations_in"]),
        ("evictions", lambda t: t["evictions"]),
    ]
    xticks, xlabels = [], []
    x = 0.0
    for row in rows:
        tiers = row["tier_rows"]
        group_mid = x + (len(tiers) - 1) / 2.0
        for t in tiers:
            for si, (_name, pick) in enumerate(series):
                ax.bar(x + si * 0.25 - 0.25, pick(t), width=0.25,
                       color=f"C{si}")
            ax.annotate(f"t{t['tier']}", (x, 0), xytext=(0, -12),
                        textcoords="offset points", ha="center",
                        fontsize=7)
            x += 1.0
        xticks.append(group_mid)
        xlabels.append(cell_label(row))
        x += 0.8  # gap between cells
    for si, (name, _pick) in enumerate(series):
        ax.bar(0, 0, color=f"C{si}", label=name)
    ax.set_xticks(xticks)
    ax.set_xticklabels(xlabels, rotation=20, ha="right", fontsize=7)
    ax.set_ylabel("requests")
    ax.set_title("dlio tier-sweep: per-tier placement")
    ax.legend(fontsize=8)
    ax.grid(True, axis="y", alpha=0.3)
    if ax2 is not None:
        for pol in sorted(curves):
            thetas = [th for th, _hf in curves[pol]]
            fracs = [hf for _th, hf in curves[pol]]
            ax2.plot(thetas, fracs, marker="o", label=pol)
        ax2.set_xlabel("zipf theta (0 = uniform)")
        ax2.set_ylabel("tier-0 hit fraction")
        ax2.set_ylim(0, 1)
        ax2.set_title("policy vs skew")
        ax2.legend(fontsize=8)
        ax2.grid(True, alpha=0.3)
    fig.tight_layout()
    fig.savefig(out, dpi=120)
    print(f"wrote {out}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("sweep_json",
                    help="output of dlio tier-sweep --format json")
    ap.add_argument("--out", default="tier-sweep.png", help="PNG path")
    ap.add_argument("--workload", default="",
                    help="filter to one workload (hot|zipf|uniform|ckpt)")
    args = ap.parse_args()
    rows = load_rows(args.sweep_json, args.workload)
    try:
        plot(rows, args.out)
    except ImportError:
        ascii_summary(rows)
    return 0


if __name__ == "__main__":
    sys.exit(main())
