#!/usr/bin/env python3
"""Plot the `dlio overlap-sweep --format json` matrix (DESIGN.md §16).

Reads the sweep's JSON rows (one object per (target, shards, prefetch)
cell, schema in EXPERIMENTS.md) and renders the paper's prefetcher
figure: one line per (target, shards), step time vs prefetch depth,
with the cell's analytic anchors — max(compute, input) for the overlap
regime and compute + input for the synchronous one — drawn as dashed
reference levels.

Stub-safe: when matplotlib is unavailable (offline CI), prints an
aligned ASCII summary of the same numbers instead of an image and
exits 0 — the JSON schema is exercised either way.

Usage:
    dlio overlap-sweep --format json > overlap.json
    python3 python/plot_overlap_sweep.py overlap.json --out overlap.png \
        [--metric step_ms]
"""

import argparse
import json
import sys

# Metric name -> extractor over one sweep row.
METRICS = {
    "step_ms": lambda row: row["step_ms"],
    "stall_frac": lambda row: row["stall_frac"],
    "overlap_frac": lambda row: row["overlap_frac"],
    "eff_io_ms_per_step": lambda row: row["eff_io_ms_per_step"],
    "images_per_sec": lambda row: row["images_per_sec"],
}


def load_rows(path):
    with open(path) as f:
        rows = json.load(f)
    if not isinstance(rows, list) or not rows:
        raise SystemExit(f"{path}: expected a non-empty JSON array of rows")
    for key in ("target", "shards", "prefetch", "step_ms",
                "compute_ms_per_step", "input_ms_per_step"):
        if key not in rows[0]:
            raise SystemExit(f"{path}: row missing {key!r} (schema drift?)")
    return rows


def curves(rows, metric):
    """(target, shards) -> sorted [(prefetch, value)], plus anchors."""
    out = {}
    anchors = {}
    pick = METRICS[metric]
    for row in rows:
        key = (row["target"], int(row["shards"]))
        out.setdefault(key, []).append((int(row["prefetch"]), pick(row)))
        c = row["compute_ms_per_step"]
        i = row["input_ms_per_step"]
        anchors[key] = (max(c, i), c + i)
    return {k: sorted(v) for k, v in out.items()}, anchors


def ascii_summary(series, anchors, metric):
    print(f"# overlap-sweep: {metric} vs prefetch depth (matplotlib "
          "unavailable: ASCII fallback)")
    width = max(len(f"{t} s={s}") for t, s in series) + 2
    for (target, shards), points in sorted(series.items()):
        label = f"{target} s={shards}".ljust(width)
        vals = "  ".join(f"p={p}:{v:.3f}" for p, v in points)
        hi, lo = anchors[(target, shards)]
        print(f"{label}{vals}  [max(C,I)={hi:.3f} C+I={lo:.3f}]")


def plot(series, anchors, metric, out):
    import matplotlib
    matplotlib.use("Agg")
    import matplotlib.pyplot as plt

    fig, ax = plt.subplots(figsize=(6, 4))
    for (target, shards), points in sorted(series.items()):
        xs = [p for p, _ in points]
        ys = [v for _, v in points]
        line, = ax.plot(xs, ys, marker="o", label=f"{target}, {shards} shards")
        if metric == "step_ms":
            overlap, additive = anchors[(target, shards)]
            color = line.get_color()
            ax.axhline(overlap, color=color, linestyle="--", alpha=0.5,
                       linewidth=0.8)
            ax.axhline(additive, color=color, linestyle=":", alpha=0.5,
                       linewidth=0.8)
    ax.set_xlabel("prefetch depth (0 = synchronous)")
    ax.set_ylabel(metric)
    title = "dlio overlap-sweep"
    if metric == "step_ms":
        title += "  (-- max(C,I), .. C+I)"
    ax.set_title(title)
    ax.legend(fontsize=8)
    ax.grid(True, alpha=0.3)
    fig.tight_layout()
    fig.savefig(out, dpi=120)
    print(f"wrote {out}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("sweep_json",
                    help="output of dlio overlap-sweep --format json")
    ap.add_argument("--out", default="overlap-sweep.png", help="PNG path")
    ap.add_argument("--metric", default="step_ms", choices=sorted(METRICS))
    args = ap.parse_args()
    series, anchors = curves(load_rows(args.sweep_json), args.metric)
    try:
        plot(series, anchors, args.metric, args.out)
    except ImportError:
        ascii_summary(series, anchors, args.metric)
    return 0


if __name__ == "__main__":
    sys.exit(main())
