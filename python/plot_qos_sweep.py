#!/usr/bin/env python3
"""Plot the `dlio qos-sweep --format json` matrix (ROADMAP follow-up).

Reads the sweep's JSON rows (one object per (mode, interval, shards)
cell, schema in EXPERIMENTS.md) and renders the Fig. 4/8-style curves:
one line per (mode, checkpoint interval), ingest metric vs reader
shards.

Stub-safe: when matplotlib is unavailable (offline CI), prints an
aligned ASCII summary of the same numbers instead of an image and
exits 0 — the JSON schema is exercised either way.

Usage:
    dlio qos-sweep --format json > sweep.json
    python3 python/plot_qos_sweep.py sweep.json --out sweep.png \
        [--metric ingest_p99_queue_ms]
"""

import argparse
import json
import sys

# Metric name -> extractor over one sweep cell.
METRICS = {
    "ingest_p99_queue_ms": lambda row: row["ingest"]["p99_queue_ms"],
    "ingest_mean_queue_ms": lambda row: row["ingest"]["mean_queue_ms"],
    "ingest_max_qdepth": lambda row: row["ingest"]["max_qdepth"],
    "images_per_sec": lambda row: row["images_per_sec"],
    "ckpt_p99_queue_ms": lambda row: row["checkpoint"]["p99_queue_ms"],
}


def load_rows(path):
    with open(path) as f:
        rows = json.load(f)
    if not isinstance(rows, list) or not rows:
        raise SystemExit(f"{path}: expected a non-empty JSON array of cells")
    for key in ("mode", "interval", "shards", "ingest"):
        if key not in rows[0]:
            raise SystemExit(f"{path}: cell missing {key!r} (schema drift?)")
    return rows


def curves(rows, metric):
    """(mode, interval) -> sorted [(shards, value)]."""
    out = {}
    pick = METRICS[metric]
    for row in rows:
        out.setdefault((row["mode"], int(row["interval"])), []).append(
            (int(row["shards"]), pick(row))
        )
    return {k: sorted(v) for k, v in out.items()}


def ascii_summary(series, metric):
    print(f"# qos-sweep: {metric} vs shards (matplotlib unavailable: "
          "ASCII fallback)")
    width = max(len(f"{mode} i={iv}") for mode, iv in series) + 2
    for (mode, iv), points in sorted(series.items()):
        label = f"{mode} i={iv}".ljust(width)
        vals = "  ".join(f"s={s}:{v:.3f}" for s, v in points)
        print(f"{label}{vals}")


def plot(series, metric, out):
    import matplotlib
    matplotlib.use("Agg")
    import matplotlib.pyplot as plt

    fig, ax = plt.subplots(figsize=(6, 4))
    for (mode, iv), points in sorted(series.items()):
        xs = [s for s, _ in points]
        ys = [v for _, v in points]
        ax.plot(xs, ys, marker="o", label=f"{mode}, ckpt interval {iv}")
    ax.set_xlabel("reader shards")
    ax.set_ylabel(metric)
    ax.set_title("dlio qos-sweep")
    ax.legend(fontsize=8)
    ax.grid(True, alpha=0.3)
    fig.tight_layout()
    fig.savefig(out, dpi=120)
    print(f"wrote {out}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("sweep_json", help="output of dlio qos-sweep --format json")
    ap.add_argument("--out", default="qos-sweep.png", help="PNG path")
    ap.add_argument(
        "--metric",
        default="ingest_p99_queue_ms",
        choices=sorted(METRICS),
    )
    args = ap.parse_args()
    series = curves(load_rows(args.sweep_json), args.metric)
    try:
        plot(series, args.metric, args.out)
    except ImportError:
        ascii_summary(series, args.metric)
    return 0


if __name__ == "__main__":
    sys.exit(main())
